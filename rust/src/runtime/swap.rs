//! Proactive swap runtime: executes an [`OffloadPlan`] during training.
//!
//! The paper's stated future work — "we can swap in and out proactively
//! in background" — falls out of Algorithm 1's execution orders: every
//! tensor access point is known before training starts, so eviction and
//! prefetch are *scheduled*, not demand-paged. The engine is
//! **full-duplex**: a background fetch worker streams prefetches in
//! while a background evict worker streams write tickets out, and the
//! training thread only ever waits at a *barrier* — the point where the
//! schedule actually needs a transfer to have finished. The protocol,
//! per training step at execution order `e`:
//!
//! 1. **pre-step, write barriers** — every eviction whose region is
//!    *reclaimed* at or before `e` (a gap tenant placed on an
//!    overlapping address range makes its first CPU write, or an early
//!    reacquire lands there) must have completed its store copy; if the
//!    ticket is still in flight, block (counted as **write stall**). A
//!    write whose gap is never reclaimed never blocks compute at all.
//! 2. **pre-step, read barriers** — complete every prefetch whose
//!    barrier EO (`prefetch_before − lead`, per entry) has arrived:
//!    copy the staged bytes back into the tensor's pool region
//!    ([`MemoryPool::reacquire`]). If the background fetch has not
//!    finished, block (counted as **read stall**); if it was never
//!    issued (gap shorter than the issue horizon), fetch inline.
//! 3. **residency guard** — no offloaded tensor may be `Evicted` or
//!    `Fetching` at one of its own use EOs. Any violation means the plan
//!    and the runtime have drifted; the step fails loudly instead of
//!    computing on poisoned data.
//! 4. **execute the layer phase** (the executor's job).
//! 5. **post-step** — every entry with `evict_after == e` becomes a
//!    write ticket: the evict worker copies the region to the
//!    [`SecondaryStore`] while training continues; the region is
//!    released ([`MemoryPool::release_gap`]) when the completion is
//!    observed. Then the background prefetch queue is topped up
//!    (deadline-ordered, up to the current depth in flight).
//!
//! Leads come from the offload plan and are shared with the gap-aware
//! planner/validator through `OffloadPlan::lead_map`, on **both** sides
//! of each gap: the read lead front-widens the next segment's
//! reservation, the write lead end-extends the previous segment's, so
//! the pool layout and the runtime barriers cannot disagree. Under
//! `SwapTuning::Calibrated` the runtime additionally records *observed*
//! per-entry fetch/evict wall times (EWMA) every iteration and keeps
//! re-deriving read leads and the in-flight depth within each entry's
//! safe bound — not just during the warmup iterations. None of this
//! affects results: tuning only moves *when* copies happen, and every
//! pool copy stays on the training thread at a deterministic step
//! boundary.
//!
//! The fetch worker touches only the store and its own staging buffers.
//! The evict worker additionally *reads* the evicted pool region
//! through a raw span — safe because the training thread never writes
//! that range before the ticket's completion is observed (the reclaim
//! barrier), and [`SwapExec`]'s drop joins both workers before the pool
//! can die (`Executor` declares its swap field before its pool). Every
//! pool *write* still happens on the training thread at a deterministic
//! point in the step order, which is what keeps swapped and unswapped
//! training bitwise identical (see `rust/tests/swap_equivalence.rs` and
//! `rust/tests/swap_stress.rs`).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::planner::compact::{frag_gauge, CompactionPlan};
use crate::planner::offload::{live_intervals, OffloadPlan};
use crate::planner::pool::MemoryPool;
use crate::tensor::{Region, Residency, TensorId, TensorTable};

use super::calibrate::{lead_for_ns, wrap_lead_for_ns, SwapCalibration};
use super::store::{SecondaryStore, StoreStats};

pub use crate::planner::offload::PREFETCH_DEPTH;

/// EWMA factor for observed transfer/compute times under `Fixed` tuning
/// (telemetry only; `Calibrated` carries its own in `SwapCalibration`).
const DEFAULT_EWMA_ALPHA: f64 = 0.25;

/// Default cap on retained epoch-boundary [`SwapStats`] snapshots —
/// generous (a mark is ~100 bytes, so the ring tops out around 100 KiB)
/// but bounded, so a long-running fleet session cannot leak memory
/// across thousands of epochs. Configurable per engine via
/// [`SwapExec::set_epoch_mark_cap`].
pub const EPOCH_MARK_CAP: usize = 1024;

/// One scheduled gap of one tensor (a tensor with several idle gaps per
/// iteration has one entry per gap).
struct SwapEntry {
    tensor: TensorId,
    name: String,
    region: Region,
    evict_after: u32,
    prefetch_before: u32,
    /// Completion-barrier lead: the reacquire happens at the pre-step of
    /// EO `prefetch_before − lead`.
    lead: u32,
    /// Barrier EO (`prefetch_before − lead`, saturated).
    due: u32,
    /// Widest lead whose early reacquire cannot collide with any other
    /// tensor placed on an overlapping address range — the bound for
    /// runtime re-derivation (plan leads are ≤ this by validation).
    max_lead: u32,
    /// Plan-side write lead (EOs past `evict_after` the region stays
    /// reserved for the in-flight eviction write).
    write_lead: u32,
    /// Write-completion barrier EO: the first EO at which another
    /// placed tensor's reserved interval touches this entry's address
    /// range after the eviction (`u32::MAX` when the gap is never
    /// reclaimed — such a write never blocks compute). The plan's write
    /// lead guarantees `reclaim_eo > evict_after + write_lead`.
    reclaim_eo: u32,
    /// Boundary (wrap) entry: the gap wraps the schedule end. Evicted at
    /// `evict_after` late in iteration N, restored at `due` early in
    /// iteration N+1 — the eviction/prefetch state is *carried* across
    /// `end_iteration` instead of drained.
    wrap: bool,
    /// For wrap entries only: the first EO at which a tensor placed in
    /// the schedule-*head* part of the free window writes the range —
    /// the carried eviction write from the previous iteration must have
    /// landed by then. `u32::MAX` when no head tenant exists. (The tail
    /// side is `reclaim_eo`, as for any entry.)
    head_reclaim_eo: u32,
}

/// Use points of an offloaded root tensor, for the residency guard.
struct RootInfo {
    name: String,
    eos: Vec<u32>,
}

/// Raw view of a pool region, shipped to the evict worker with a write
/// ticket.
///
/// # Safety contract
/// The training thread must not write the spanned range until the
/// ticket's completion is observed (the reclaim barrier enforces this;
/// the planner's write-lead reservation keeps tenants away), and the
/// pool must outlive the worker ([`SwapExec`]'s drop joins the workers;
/// `Executor` declares `swap` before `pool` so the join runs first).
struct PoolSpan {
    ptr: *const f32,
    len: usize,
}

unsafe impl Send for PoolSpan {}

enum Req {
    Fetch(usize),
    Write(usize, PoolSpan),
    Stop,
}

enum Done {
    /// `(entry, staged data, wall ns)` — from the fetch worker.
    Fetch(usize, Result<Vec<f32>>, u64),
    /// `(entry, store-put result, wall ns)` — from the evict worker.
    Write(usize, Result<()>, u64),
}

/// Cumulative swap-runtime counters (whole run, not per iteration).
///
/// Epoch-boundary snapshots of these counters are retained in a ring
/// buffer capped at [`EPOCH_MARK_CAP`] marks by default
/// ([`SwapExec::set_epoch_mark_cap`] to change): a fleet session running
/// for thousands of epochs keeps a bounded trajectory, and
/// [`SwapExec::epoch_stats`] deltas stay correct across the wrap — the
/// last dropped mark becomes the delta base for the oldest retained one.
#[derive(Clone, Copy, Debug, Default)]
pub struct SwapStats {
    pub evictions: u64,
    pub prefetches: u64,
    /// Prefetches that had to run inline on the training thread because
    /// the gap was shorter than the issue horizon.
    pub sync_fetches: u64,
    pub bytes_out: u64,
    pub bytes_in: u64,
    /// Wall time the training thread spent waiting on swap-ins (read
    /// barriers and inline fetches).
    pub read_stall_ns: u64,
    /// Wall time the training thread spent waiting on eviction writes
    /// (reclaim barriers; under synchronous evictions, the writes
    /// themselves).
    pub write_stall_ns: u64,
    /// The subset of `read_stall_ns` accrued restoring *boundary* (wrap)
    /// entries — carried prefetches completing in the first `max_lead`
    /// EOs of an iteration. With cross-iteration pipelining the fetch
    /// worker pulls these during the previous iteration's tail and the
    /// boundary itself, so this approaches zero; with a full boundary
    /// drain every wrap restore runs inline here.
    pub boundary_stall_ns: u64,
    /// Pool-arena size in bytes — a *gauge* (layout snapshot), not a
    /// cumulative counter. Refreshed at build and after compaction.
    pub pool_bytes: u64,
    /// Pool bytes no tensor region ever covers (placement waste — the
    /// fragmentation the `frag_pct` bench column gates).
    pub frag_bytes: u64,
    /// Longest contiguous never-covered run in the pool (includes the
    /// tail a compaction shrink reclaims).
    pub largest_free_extent_bytes: u64,
}

impl SwapStats {
    /// Total training-thread wait on swap traffic, ns.
    pub fn stall_ns(&self) -> u64 {
        self.read_stall_ns + self.write_stall_ns
    }

    pub fn stall_ms(&self) -> f64 {
        self.stall_ns() as f64 / 1e6
    }

    pub fn read_stall_ms(&self) -> f64 {
        self.read_stall_ns as f64 / 1e6
    }

    pub fn write_stall_ms(&self) -> f64 {
        self.write_stall_ns as f64 / 1e6
    }

    pub fn boundary_stall_ms(&self) -> f64 {
        self.boundary_stall_ns as f64 / 1e6
    }

    /// Never-covered pool fraction, percent (gauge).
    pub fn frag_pct(&self) -> f64 {
        if self.pool_bytes == 0 {
            0.0
        } else {
            self.frag_bytes as f64 / self.pool_bytes as f64 * 100.0
        }
    }

    /// Counter-wise difference against an earlier snapshot of the same
    /// run — the per-epoch deltas behind [`SwapExec::epoch_stats`].
    /// Saturating: a reset (new run) never underflows into garbage.
    /// Gauges (`pool_bytes`, `frag_bytes`, `largest_free_extent_bytes`)
    /// carry the *current* snapshot's values — a layout state has no
    /// meaningful per-epoch difference.
    pub fn delta(&self, prev: &SwapStats) -> SwapStats {
        SwapStats {
            evictions: self.evictions.saturating_sub(prev.evictions),
            prefetches: self.prefetches.saturating_sub(prev.prefetches),
            sync_fetches: self.sync_fetches.saturating_sub(prev.sync_fetches),
            bytes_out: self.bytes_out.saturating_sub(prev.bytes_out),
            bytes_in: self.bytes_in.saturating_sub(prev.bytes_in),
            read_stall_ns: self.read_stall_ns.saturating_sub(prev.read_stall_ns),
            write_stall_ns: self.write_stall_ns.saturating_sub(prev.write_stall_ns),
            boundary_stall_ns: self.boundary_stall_ns.saturating_sub(prev.boundary_stall_ns),
            pool_bytes: self.pool_bytes,
            frag_bytes: self.frag_bytes,
            largest_free_extent_bytes: self.largest_free_extent_bytes,
        }
    }
}

/// EWMA with first-sample snap: an empty slot takes the sample outright.
/// `pub(crate)` — the fleet scheduler reuses it for its unpark/step
/// latency models, keeping one smoothing semantic across the runtime.
pub(crate) fn ewma_update(slot: &mut f64, sample: f64, alpha: f64) {
    *slot = if *slot > 0.0 { *slot + alpha * (sample - *slot) } else { sample };
}

/// Derive every entry's placement-dependent bounds from the placed
/// table: `max_lead` (widest safe read lead) and `reclaim_eo` (write
/// completion barrier). Runs at construction and again after a pool
/// compaction rebinds the regions — the bounds depend on which tensors
/// share addresses, which is exactly what relocation changes. The floor
/// for `max_lead` is the *plan* lead (entries correspond 1:1 with
/// `plan.entries`, in order): the relocated layout re-validates under
/// the plan's lead map, so the plan lead is always safe.
fn derive_entry_bounds(entries: &mut [SwapEntry], plan: &OffloadPlan, table: &TensorTable) {
    let leads = plan.lead_map();
    let offloaded: HashSet<TensorId> = plan.entries.iter().map(|e| e.tensor).collect();
    for (k, entry) in entries.iter_mut().enumerate() {
        // A wrap entry's free window wraps the boundary: the schedule
        // head `[0, due)` is part of it, so the widest-lead floor starts
        // at EO 0 (lead up to `prefetch_before` puts the barrier at EO
        // 0), and tenants split into *head* (intervals before the
        // restore) and *tail* (after the eviction) — each side gets its
        // own write-completion barrier.
        let mut earliest = if entry.wrap { 0 } else { entry.evict_after + 1 };
        let mut reclaim = u32::MAX;
        let mut head_reclaim = u32::MAX;
        for s in table.iter() {
            if s.merged_into.is_some() || s.eos.is_empty() || s.id == entry.tensor {
                continue;
            }
            let Some(r) = s.region else { continue };
            let overlap = r.offset < entry.region.end() && entry.region.offset < r.end();
            if !overlap {
                continue;
            }
            for (a, z) in live_intervals(s, offloaded.contains(&s.id).then_some(&leads)) {
                if z < entry.prefetch_before {
                    earliest = earliest.max(z + 1);
                }
                if a > entry.evict_after {
                    reclaim = reclaim.min(a);
                }
                if entry.wrap && a < entry.prefetch_before {
                    // head tenant: its first write next iteration races
                    // the *carried* eviction write of this iteration
                    head_reclaim = head_reclaim.min(a);
                }
            }
        }
        entry.max_lead = (entry.prefetch_before - earliest).max(plan.entries[k].lead);
        entry.reclaim_eo = reclaim;
        entry.head_reclaim_eo = head_reclaim;
    }
}

/// Build the write-completion barrier records: one `(reclaim_eo, i)` per
/// entry, plus a second `(head_reclaim_eo, i)` record for wrap entries
/// with a schedule-head tenant. Sorted by EO for the single-cursor walk.
fn build_reclaim_records(entries: &[SwapEntry]) -> Vec<(u32, usize)> {
    let mut records: Vec<(u32, usize)> = Vec::with_capacity(entries.len() + 4);
    for (i, e) in entries.iter().enumerate() {
        records.push((e.reclaim_eo, i));
        if e.wrap && e.head_reclaim_eo != u32::MAX {
            records.push((e.head_reclaim_eo, i));
        }
    }
    records.sort_unstable();
    records
}

/// Pairwise address-overlap sets over the (current) entry regions.
fn compute_overlaps(entries: &[SwapEntry]) -> Vec<Vec<usize>> {
    let n = entries.len();
    let mut overlaps: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j
                && entries[i].region.offset < entries[j].region.end()
                && entries[j].region.offset < entries[i].region.end()
            {
                overlaps[i].push(j);
            }
        }
    }
    overlaps
}

/// Executable swap schedule bound to one compiled model's pool layout.
pub struct SwapExec {
    entries: Vec<SwapEntry>,
    plan: OffloadPlan,
    /// EO → entries to evict right after the step at that EO.
    evict_at: HashMap<u32, Vec<usize>>,
    /// Entry indices sorted by barrier EO (`due`) — both the completion
    /// barrier order and the background issue order.
    by_prefetch: Vec<usize>,
    /// Write-completion barrier records `(barrier EO, entry)`, sorted by
    /// EO. A non-wrap entry has one record (its `reclaim_eo`); a wrap
    /// entry may have two — the head-tenant barrier early in the
    /// schedule (where the *carried* write from the previous iteration
    /// must land) and the tail-tenant barrier after its eviction. One
    /// cursor walks the records once per iteration; a record whose entry
    /// has no in-flight eviction write is a no-op.
    by_reclaim: Vec<(u32, usize)>,
    /// Per entry, the other entries whose regions share addresses with
    /// it. A reacquire writes the entry's range, and observed-feedback
    /// lead widening can move it ahead of the other entry's reclaim
    /// barrier EO — so the reacquire itself waits out their in-flight
    /// eviction writes.
    overlaps: Vec<Vec<usize>>,
    roots: HashMap<TensorId, RootInfo>,
    residency: HashMap<TensorId, Residency>,
    // per-iteration entry state
    evicted: Vec<bool>,
    /// Eviction write landed in the store (ticket completed, or the
    /// synchronous put returned).
    evict_done: Vec<bool>,
    issued: Vec<bool>,
    restored: Vec<bool>,
    staged: HashMap<usize, Vec<f32>>,
    failed: HashMap<usize, Error>,
    write_failed: HashMap<usize, Error>,
    next_due: usize,
    next_reclaim: usize,
    issue_cursor: usize,
    outstanding: usize,
    outstanding_writes: usize,
    /// How many of `outstanding` fetches belong to wrap entries — the
    /// transfers `end_iteration` may legitimately leave in flight.
    wrap_fetches_inflight: usize,
    /// How many of `outstanding_writes` belong to wrap entries.
    wrap_writes_inflight: usize,
    store: Arc<Mutex<Box<dyn SecondaryStore>>>,
    store_kind: &'static str,
    fetch_tx: Sender<Req>,
    evict_tx: Sender<Req>,
    done_rx: Receiver<Done>,
    /// Staging buffers handed back to the fetch worker for reuse,
    /// keeping the steady-state prefetch path allocation-free.
    recycle_tx: Sender<Vec<f32>>,
    /// Reusable staging buffer for inline (never-issued) fetches on the
    /// training thread — sized to the widest entry at construction so
    /// the sync-fallback path stays allocation-free too.
    inline_buf: Vec<f32>,
    workers: Vec<JoinHandle<()>>,
    /// Current in-flight fetch budget (plan's initial depth; grows via
    /// observed-feedback re-derivation and [`SwapExec::adapt_depth`]).
    depth: usize,
    /// Run evictions synchronously on the training thread (the PR-1
    /// behaviour) instead of as background write tickets. Bitwise
    /// identical either way; exists so benches can measure what the
    /// write pipeline takes off the critical path.
    sync_evictions: bool,
    /// Fully drain wrap transfers at `end_iteration` and never issue
    /// their fetches in the background — the non-pipelined boundary
    /// baseline (every wrap restore becomes an inline fetch at its due
    /// EO, accrued as boundary stall). Bitwise identical either way;
    /// exists so benches can show what the cross-iteration pipeline
    /// takes off the boundary.
    boundary_drain: bool,
    /// Calibration state for runtime refinement (None under Fixed).
    calibration: Option<SwapCalibration>,
    ewma_alpha: f64,
    /// Observed per-entry fetch wall times, EWMA ns (0 = no sample).
    fetch_observed_ns: Vec<f64>,
    /// Observed per-entry evict wall times, EWMA ns (0 = no sample).
    evict_observed_ns: Vec<f64>,
    /// Observed compute time per full iteration (wall minus stalls),
    /// EWMA ns.
    compute_observed_ns: f64,
    /// Warmup timing: iterations measured so far and their accumulated
    /// compute ns (stalls excluded — untimed forward passes also accrue
    /// stalls, which must not skew the compute estimate).
    warmup_done: u64,
    warmup_compute_ns: u64,
    /// Wall-clock start and total-stall snapshot of a timed (full
    /// training) iteration.
    iter_start: Option<(Instant, u64)>,
    /// Stall counter snapshot at the last `adapt_depth` call.
    last_stall_ns: u64,
    pub stats: SwapStats,
    /// Cumulative-counter snapshots taken at each `mark_epoch` call —
    /// the perf harness reads the trajectory as per-epoch deltas
    /// (`epoch_stats`) instead of only whole-run totals. A bounded ring:
    /// past `epoch_mark_cap` marks the oldest snapshot is dropped into
    /// `epoch_base`, which keeps the first retained delta correct.
    epoch_marks: VecDeque<SwapStats>,
    epoch_mark_cap: usize,
    /// The last mark dropped off the ring's front (zero until the ring
    /// wraps) — the delta base for the oldest retained mark.
    epoch_base: SwapStats,
    /// Plan-time pool-relocation map, parked here until the executor
    /// applies it at the first swap-quiescent epoch barrier
    /// (`Executor::compact_pool` takes it, moves the persistent bytes,
    /// shrinks the pool, and calls [`SwapExec::rebind`]).
    compaction: Option<CompactionPlan>,
}

impl SwapExec {
    /// Build the schedule from a planned table (regions assigned by the
    /// gap-aware planner) and spawn the background fetch + evict
    /// workers.
    ///
    /// Every entry's leads must leave room inside the gap: the read
    /// barrier strictly after the eviction
    /// (`prefetch_before > evict_after + lead`) and the write extension
    /// strictly before the read widening
    /// (`prefetch_before > evict_after + lead + write_lead`). A lead
    /// pair that swallows the gap would fire the prefetch barrier
    /// before the gap opens: the entry would be judged "still resident"
    /// while its fetch was never issued, and from the *next* iteration
    /// on training would silently read whatever the gap tenant left in
    /// the region — the schedule-head edge this constructor rejects
    /// loudly.
    pub fn new(
        table: &TensorTable,
        plan: &OffloadPlan,
        store: Box<dyn SecondaryStore>,
        calibration: Option<SwapCalibration>,
    ) -> Result<SwapExec> {
        let schedule_end = table.iter().filter_map(|s| s.max_eo()).max().unwrap_or(0);
        let mut entries = Vec::with_capacity(plan.entries.len());
        let mut roots: HashMap<TensorId, RootInfo> = HashMap::new();
        let mut residency: HashMap<TensorId, Residency> = HashMap::new();
        for e in &plan.entries {
            let s = table.get(e.tensor);
            if e.wrap {
                // Boundary entry: the gap wraps the schedule end, so the
                // geometry constraints invert — the restore barrier must
                // fit inside the schedule head and the write reservation
                // inside the tail.
                if e.prefetch_before < 1 || e.lead < 1 || e.lead > e.prefetch_before {
                    return Err(Error::planner(format!(
                        "wrap entry for `{}` has lead {} that does not fit before its \
                         first access EO {}",
                        s.name, e.lead, e.prefetch_before
                    )));
                }
                if e.prefetch_before > e.evict_after {
                    return Err(Error::planner(format!(
                        "wrap entry for `{}` does not wrap: prefetch_before {} > \
                         evict_after {}",
                        s.name, e.prefetch_before, e.evict_after
                    )));
                }
                if e.evict_after.saturating_add(e.write_lead) > schedule_end {
                    return Err(Error::planner(format!(
                        "wrap entry for `{}` has write reservation {}+{} past the \
                         schedule end {}",
                        s.name, e.evict_after, e.write_lead, schedule_end
                    )));
                }
            } else {
                if e.evict_after >= e.prefetch_before {
                    return Err(Error::planner(format!(
                        "offload entry for `{}` has an empty gap ({} >= {})",
                        s.name, e.evict_after, e.prefetch_before
                    )));
                }
                if e.prefetch_before <= e.evict_after.saturating_add(e.lead) {
                    return Err(Error::planner(format!(
                        "offload entry for `{}` has lead {} swallowing its gap ({}, {}): \
                         the prefetch barrier would fire before the eviction",
                        s.name, e.lead, e.evict_after, e.prefetch_before
                    )));
                }
                if e.prefetch_before
                    <= e.evict_after.saturating_add(e.lead).saturating_add(e.write_lead)
                {
                    return Err(Error::planner(format!(
                        "offload entry for `{}` has write lead {} (with read lead {}) \
                         swallowing its gap ({}, {}): the write extension would meet the \
                         prefetch reservation",
                        s.name, e.write_lead, e.lead, e.evict_after, e.prefetch_before
                    )));
                }
            }
            let region = s.region.ok_or_else(|| {
                Error::planner(format!("offloaded tensor `{}` has no region", s.name))
            })?;
            entries.push(SwapEntry {
                tensor: e.tensor,
                name: s.name.clone(),
                region,
                evict_after: e.evict_after,
                prefetch_before: e.prefetch_before,
                lead: e.lead,
                due: e.prefetch_before.saturating_sub(e.lead),
                max_lead: e.lead, // widened below from the placed table
                write_lead: e.write_lead,
                reclaim_eo: u32::MAX, // narrowed below from the placed table
                wrap: e.wrap,
                head_reclaim_eo: u32::MAX, // narrowed below from the placed table
            });
            // Residency-guard use points. A wrap tensor's *recorded* EOs
            // are the conservative whole-schedule bracket (persistent
            // tensors are pinned `{0, last}` by the assembler), but under
            // the boundary window its real accesses are exactly
            // `[prefetch_before, evict_after]` — guarding the recorded
            // EO 0 would fire on every carried entry at the first step.
            let guard_eos = if e.wrap {
                vec![e.prefetch_before, e.evict_after]
            } else {
                s.eos.clone()
            };
            roots
                .entry(e.tensor)
                .or_insert_with(|| RootInfo { name: s.name.clone(), eos: guard_eos });
            residency.insert(e.tensor, Residency::Resident);
        }
        // Per-entry bounds from the placed table. For every *other*
        // tensor placed on an overlapping address range, its reserved
        // intervals under the plan's own leads give:
        // * `max_lead` — the earliest EO at which the entry's region is
        //   free of everyone before its next use; runtime re-derivation
        //   may widen a read lead up to this without colliding with a
        //   gap tenant.
        // * `reclaim_eo` — the first EO at which anyone touches the
        //   range after the eviction: the write ticket's completion
        //   barrier. (A tenant's plan-widened interval start is its
        //   first CPU write — an early reacquire copies into the range
        //   at exactly that EO.)
        derive_entry_bounds(&mut entries, plan, table);
        let n = entries.len();
        let overlaps = compute_overlaps(&entries);
        let mut evict_at: HashMap<u32, Vec<usize>> = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            evict_at.entry(e.evict_after).or_default().push(i);
        }
        let mut by_prefetch: Vec<usize> = (0..n).collect();
        by_prefetch.sort_by_key(|&i| (entries[i].due, entries[i].prefetch_before, i));
        let by_reclaim = build_reclaim_records(&entries);

        let store_kind = store.kind();
        let store = Arc::new(Mutex::new(store));
        let (fetch_tx, fetch_rx) = channel::<Req>();
        let (evict_tx, evict_rx) = channel::<Req>();
        let (done_tx, done_rx) = channel::<Done>();
        let (recycle_tx, recycle_rx) = channel::<Vec<f32>>();
        let lens: Vec<usize> = entries.iter().map(|e| e.region.len).collect();
        // Widest entry: staging buffers are grown to this once so a small
        // recycled buffer meeting a larger entry never reallocates on the
        // steady-state path (pinned by tests/swap_alloc_audit.rs).
        let max_len = lens.iter().copied().max().unwrap_or(0);

        let fstore = Arc::clone(&store);
        let fetch_done = done_tx.clone();
        let fetch_worker = std::thread::Builder::new()
            .name("nntrainer-prefetch".into())
            .spawn(move || {
                crate::runtime::alloc_audit::mark_thread_tracked();
                while let Ok(req) = fetch_rx.recv() {
                    match req {
                        Req::Fetch(i) => {
                            // reuse a returned staging buffer when one is
                            // available — steady state allocates nothing
                            let mut buf = recycle_rx.try_recv().unwrap_or_default();
                            if buf.capacity() < max_len {
                                buf.reserve_exact(max_len - buf.len());
                            }
                            if buf.len() != lens[i] {
                                buf.resize(lens[i], 0.0);
                            }
                            let t0 = Instant::now();
                            let res = fstore.lock().unwrap().get(i, &mut buf).map(|()| buf);
                            let ns = t0.elapsed().as_nanos() as u64;
                            if fetch_done.send(Done::Fetch(i, res, ns)).is_err() {
                                break;
                            }
                        }
                        _ => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn prefetch thread: {e}")))?;

        let wstore = Arc::clone(&store);
        let evict_worker = std::thread::Builder::new()
            .name("nntrainer-evict".into())
            .spawn(move || {
                crate::runtime::alloc_audit::mark_thread_tracked();
                while let Ok(req) = evict_rx.recv() {
                    match req {
                        Req::Write(i, span) => {
                            // Safety: see `PoolSpan` — the range stays
                            // immutable until this ticket's completion
                            // is observed, and the pool outlives the
                            // join in SwapExec::drop.
                            let data =
                                unsafe { std::slice::from_raw_parts(span.ptr, span.len) };
                            let t0 = Instant::now();
                            let res = wstore.lock().unwrap().put(i, data);
                            let ns = t0.elapsed().as_nanos() as u64;
                            if done_tx.send(Done::Write(i, res, ns)).is_err() {
                                break;
                            }
                        }
                        _ => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn evict thread: {e}")))?;

        let ewma_alpha = calibration
            .as_ref()
            .map(|c| c.ewma_alpha)
            .unwrap_or(DEFAULT_EWMA_ALPHA);
        Ok(SwapExec {
            entries,
            plan: plan.clone(),
            evict_at,
            by_prefetch,
            by_reclaim,
            overlaps,
            roots,
            residency,
            evicted: vec![false; n],
            evict_done: vec![false; n],
            issued: vec![false; n],
            restored: vec![false; n],
            staged: HashMap::new(),
            failed: HashMap::new(),
            write_failed: HashMap::new(),
            next_due: 0,
            next_reclaim: 0,
            issue_cursor: 0,
            outstanding: 0,
            outstanding_writes: 0,
            wrap_fetches_inflight: 0,
            wrap_writes_inflight: 0,
            store,
            store_kind,
            fetch_tx,
            evict_tx,
            done_rx,
            recycle_tx,
            inline_buf: Vec::with_capacity(max_len),
            workers: vec![fetch_worker, evict_worker],
            depth: plan.prefetch_depth.max(PREFETCH_DEPTH),
            sync_evictions: false,
            boundary_drain: false,
            calibration,
            ewma_alpha,
            fetch_observed_ns: vec![0.0; n],
            evict_observed_ns: vec![0.0; n],
            compute_observed_ns: 0.0,
            warmup_done: 0,
            warmup_compute_ns: 0,
            iter_start: None,
            last_stall_ns: 0,
            stats: SwapStats::default(),
            epoch_marks: VecDeque::new(),
            epoch_mark_cap: EPOCH_MARK_CAP,
            epoch_base: SwapStats::default(),
            compaction: None,
        })
    }

    /// Park a pool-relocation map for the executor to apply at the next
    /// swap-quiescent epoch barrier.
    pub fn set_compaction(&mut self, plan: CompactionPlan) {
        self.compaction = Some(plan);
    }

    /// Take the parked relocation map (once). Must only be consumed at a
    /// quiescent point — see [`SwapExec::rebind`].
    pub fn take_compaction(&mut self) -> Option<CompactionPlan> {
        self.compaction.take()
    }

    /// Whether a compaction is still parked (diagnostics, tests).
    pub fn has_compaction(&self) -> bool {
        self.compaction.is_some()
    }

    /// Re-bind the schedule to a relocated pool layout. Call only at a
    /// swap-quiescent point (after `end_iteration`: no outstanding
    /// transfers, nothing staged) with the table's regions already
    /// rewritten to the relocation map's destinations.
    ///
    /// What changes: entry regions, the placement-derived bounds
    /// (`max_lead`, `reclaim_eo`), the address-overlap sets, and the
    /// two barrier orders. What must NOT change: region *lengths* — the
    /// workers captured them at spawn (staging-buffer sizing), so a
    /// length change is a hard error, not a rebind.
    ///
    /// Widened runtime leads are clamped into the recomputed bounds;
    /// the plan lead is always admissible (the relocated layout
    /// re-validates under the plan's lead map).
    pub fn rebind(&mut self, table: &TensorTable) -> Result<()> {
        if self.outstanding != 0 || self.outstanding_writes != 0 || !self.staged.is_empty() {
            return Err(Error::Runtime(
                "swap runtime: rebind with transfers in flight".into(),
            ));
        }
        if self.entries.iter().enumerate().any(|(i, e)| e.wrap && self.evicted[i]) {
            return Err(Error::Runtime(
                "swap runtime: rebind with boundary entries still carried — quiesce first"
                    .into(),
            ));
        }
        for entry in self.entries.iter_mut() {
            let s = table.get(entry.tensor);
            let region = s.region.ok_or_else(|| {
                Error::planner(format!("relocated tensor `{}` lost its region", s.name))
            })?;
            if region.len != entry.region.len {
                return Err(Error::planner(format!(
                    "pool compaction changed `{}`'s region length {} -> {} — relocation \
                     may only move regions, never resize them",
                    s.name, entry.region.len, region.len
                )));
            }
            entry.region = region;
        }
        derive_entry_bounds(&mut self.entries, &self.plan, table);
        for e in self.entries.iter_mut() {
            e.lead = e.lead.clamp(1, e.max_lead);
            e.due = e.prefetch_before.saturating_sub(e.lead);
        }
        self.overlaps = compute_overlaps(&self.entries);
        self.by_prefetch
            .sort_by_key(|&i| (self.entries[i].due, self.entries[i].prefetch_before, i));
        self.by_reclaim = build_reclaim_records(&self.entries);
        Ok(())
    }

    /// Refresh the fragmentation gauges in [`SwapStats`] from a (placed)
    /// table — at build and again after compaction.
    pub fn refresh_frag(&mut self, table: &TensorTable, pool_len: usize) {
        let g = frag_gauge(table, pool_len);
        self.stats.pool_bytes = g.pool_bytes;
        self.stats.frag_bytes = g.unused_bytes;
        self.stats.largest_free_extent_bytes = g.largest_free_extent_bytes;
    }

    /// Snapshot of the secondary store's cumulative I/O counters.
    pub fn store_stats(&self) -> StoreStats {
        self.store.lock().unwrap().stats()
    }

    pub fn plan(&self) -> &OffloadPlan {
        &self.plan
    }

    pub fn store_kind(&self) -> &'static str {
        self.store_kind
    }

    /// Shared handle to the secondary store (teardown slot audits,
    /// tests). Lock only between iterations — the workers take the same
    /// lock on every transfer.
    pub fn store_handle(&self) -> Arc<Mutex<Box<dyn SecondaryStore>>> {
        Arc::clone(&self.store)
    }

    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    pub fn residency_of(&self, root: TensorId) -> Option<Residency> {
        self.residency.get(&root).copied()
    }

    /// Run evictions synchronously on the training thread (the PR-1
    /// behaviour) instead of as background write tickets. Flip only
    /// between iterations. Results are bitwise identical either way —
    /// the switch exists so benches can show what the write pipeline
    /// takes off the critical path (write stall accrues for the full
    /// store put under `true`).
    pub fn set_sync_evictions(&mut self, on: bool) {
        self.sync_evictions = on;
    }

    /// Reset per-iteration state. Every *in-iteration* entry must have
    /// been restored by the previous iteration's `end_iteration`;
    /// boundary (wrap) entries may legitimately arrive mid-cycle — their
    /// eviction from the previous iteration carried across the boundary,
    /// with its write and/or restore fetch still in flight (tracked by
    /// the wrap in-flight counters). Anything *else* in flight is stale
    /// and fails loudly. A wrap entry whose bytes are still resident
    /// (first iteration after init, or after a partial pass / drained
    /// sweep) is *primed*: synchronously evicted here, so the boundary
    /// cycle is in its steady state — evicted, restore due at `due` —
    /// at every iteration start. `full_schedule` is true for training iterations
    /// (every EO runs): only those are timed for the observed-feedback
    /// loop — a forward-only pass covers a fraction of the schedule and
    /// would skew the compute estimate.
    pub fn begin_iteration(&mut self, full_schedule: bool, pool: &MemoryPool) -> Result<()> {
        if self.outstanding != self.wrap_fetches_inflight
            || self.outstanding_writes != self.wrap_writes_inflight
            || self.staged.keys().any(|&i| !self.entries[i].wrap)
        {
            return Err(Error::Runtime(
                "swap runtime: stale transfers at iteration start".into(),
            ));
        }
        for i in 0..self.entries.len() {
            // a carried wrap entry stays mid-cycle: evicted last
            // iteration, restore due early in this one
            if self.entries[i].wrap && self.evicted[i] && !self.restored[i] {
                continue;
            }
            self.evicted[i] = false;
            self.evict_done[i] = false;
            self.issued[i] = false;
            self.restored[i] = false;
            self.residency.insert(self.entries[i].tensor, Residency::Resident);
        }
        // Prime the boundary cycle: a wrap entry whose bytes are still
        // in the pool at an iteration start (the first iteration after
        // init, or after a partial pass / boundary-drained sweep that
        // restored it) is evicted *now*, synchronously. Its freed head
        // window may be handed to a tenant before the restore barrier;
        // skipping the eviction and taking the unevicted-restore
        // shortcut at `due` would then hand the tenant's bytes to
        // compute. Two phases — every snapshot is taken before any
        // region is released — so entries whose (manually planned)
        // regions overlap snapshot mutually-consistent bytes; placed
        // plans keep wrap regions disjoint via the EO-0 init point in
        // `live_intervals`. Steady-state pipelined iterations prime
        // nothing: every wrap entry arrives carried.
        let alpha = self.ewma_alpha;
        let mut primed = false;
        for i in 0..self.entries.len() {
            let e = &self.entries[i];
            if e.wrap && !self.evicted[i] {
                let t0 = Instant::now();
                self.store.lock().unwrap().put(i, pool.view(e.region))?;
                let ns = t0.elapsed().as_nanos() as u64;
                self.stats.write_stall_ns += ns;
                ewma_update(&mut self.evict_observed_ns[i], ns as f64, alpha);
                self.stats.evictions += 1;
                self.stats.bytes_out += (e.region.len * 4) as u64;
                primed = true;
            }
        }
        if primed {
            for i in 0..self.entries.len() {
                let e = &self.entries[i];
                if e.wrap && !self.evicted[i] {
                    pool.release_gap(e.region);
                    self.evicted[i] = true;
                    self.evict_done[i] = true;
                    self.issued[i] = false;
                    self.restored[i] = false;
                    self.residency.insert(e.tensor, Residency::Evicted);
                }
            }
        }
        // a carried fetch/write failure must survive into this iteration
        // to surface at its barrier
        self.failed.retain(|&i, _| self.entries[i].wrap);
        self.write_failed.retain(|&i, _| self.entries[i].wrap);
        self.next_due = 0;
        self.next_reclaim = 0;
        self.issue_cursor = 0;
        // full iterations are timed so the calibrated cost model keeps
        // tracking reality (warmup rescale, then per-iteration EWMA)
        self.iter_start = match &self.calibration {
            Some(_) if full_schedule => Some((Instant::now(), self.stats.stall_ns())),
            _ => None,
        };
        Ok(())
    }

    /// Run the write barriers, then complete every prefetch whose
    /// barrier EO is at or before `eo`. Write barriers go first: a
    /// tenant's early reacquire at this EO is itself a CPU write into a
    /// possibly still-draining range.
    pub fn pre_step(&mut self, eo: u32, pool: &MemoryPool) -> Result<()> {
        while self.next_reclaim < self.by_reclaim.len() {
            let (barrier_eo, idx) = self.by_reclaim[self.next_reclaim];
            if barrier_eo > eo {
                break;
            }
            if self.evicted[idx] && !self.evict_done[idx] {
                self.wait_write(idx, pool)?;
            }
            if let Some(err) = self.write_failed.remove(&idx) {
                return Err(err);
            }
            self.next_reclaim += 1;
        }
        while self.next_due < self.by_prefetch.len() {
            let idx = self.by_prefetch[self.next_due];
            if self.entries[idx].due > eo {
                break;
            }
            self.finish_prefetch(idx, pool, Some(eo))?;
            self.next_due += 1;
        }
        Ok(())
    }

    /// The residency guard: no offloaded tensor may be away from primary
    /// memory at one of its own use EOs. Catches plan/runtime drift (and
    /// deliberately corrupted plans) before a layer computes on poison.
    pub fn check_residency(&self, eo: u32) -> Result<()> {
        for (id, info) in &self.roots {
            let state = self.residency.get(id).copied().unwrap_or(Residency::Resident);
            if state != Residency::Resident && info.eos.binary_search(&eo).is_ok() {
                return Err(Error::Runtime(format!(
                    "residency violation: `{}` is {:?} at EO {eo}, one of its use points — \
                     the offload plan and the swap runtime have drifted",
                    info.name, state
                )));
            }
        }
        Ok(())
    }

    /// Evict entries whose gap starts after the step at `eo` (as
    /// background write tickets, unless synchronous evictions are on),
    /// then top up the background prefetch queue.
    pub fn post_step(&mut self, eo: u32, pool: &MemoryPool) -> Result<()> {
        let alpha = self.ewma_alpha;
        let sync = self.sync_evictions;
        if let Some(idxs) = self.evict_at.get(&eo) {
            for &idx in idxs {
                let e = &self.entries[idx];
                self.evict_done[idx] = false;
                if sync {
                    let t0 = Instant::now();
                    self.store.lock().unwrap().put(idx, pool.view(e.region))?;
                    let ns = t0.elapsed().as_nanos() as u64;
                    self.stats.write_stall_ns += ns;
                    ewma_update(&mut self.evict_observed_ns[idx], ns as f64, alpha);
                    pool.release_gap(e.region);
                    self.evict_done[idx] = true;
                } else {
                    let span = PoolSpan { ptr: pool.view(e.region).as_ptr(), len: e.region.len };
                    if self.evict_tx.send(Req::Write(idx, span)).is_err() {
                        return Err(Error::Runtime("swap evict thread died".into()));
                    }
                    self.outstanding_writes += 1;
                    if e.wrap {
                        self.wrap_writes_inflight += 1;
                    }
                }
                self.evicted[idx] = true;
                self.residency.insert(e.tensor, Residency::Evicted);
                self.stats.evictions += 1;
                self.stats.bytes_out += (e.region.len * 4) as u64;
                if e.wrap {
                    // fresh boundary cycle: the restore is due early next
                    // iteration, and the issue cursor rewinds so the pump
                    // can reach this entry's schedule-head queue position
                    // once the write lands
                    self.restored[idx] = false;
                    self.issued[idx] = false;
                    self.issue_cursor = 0;
                }
            }
        }
        self.drain_completions(pool);
        self.pump_issues();
        Ok(())
    }

    /// Restore every in-iteration entry still out (e.g. a final gap
    /// whose prefetch EO has no step in this schedule), then drain the
    /// in-flight transfers so the next iteration starts clean.
    ///
    /// Boundary (wrap) entries are exempt unless the boundary drain is
    /// on: their eviction writes and restore fetches are *carried*
    /// across the boundary — that is the cross-iteration pipeline — and
    /// `begin_iteration` accepts exactly those (the wrap in-flight
    /// counters). After the drain the issue cursor rewinds and the pump
    /// runs once, so a wrap fetch whose eviction write has already
    /// landed overlaps the boundary work itself.
    ///
    /// A sweep failure no longer returns early: every transfer is
    /// drained (and carried entries force-restored) *first*, so the
    /// original error propagates instead of being masked by a
    /// misleading "stale transfers at iteration start" on the next
    /// iteration.
    pub fn end_iteration(&mut self, pool: &MemoryPool) -> Result<()> {
        let mut first_err: Option<Error> = None;
        for k in 0..self.by_prefetch.len() {
            let idx = self.by_prefetch[k];
            if self.entries[idx].wrap && !self.boundary_drain {
                continue; // carried across the boundary
            }
            if !self.restored[idx] {
                if let Err(err) = self.finish_prefetch(idx, pool, None) {
                    first_err.get_or_insert(err);
                }
            }
        }
        self.next_due = self.by_prefetch.len();
        self.next_reclaim = self.by_reclaim.len();
        let pipelined = !self.boundary_drain && first_err.is_none();
        loop {
            let (keep_f, keep_w) = if pipelined {
                (self.wrap_fetches_inflight, self.wrap_writes_inflight)
            } else {
                (0, 0)
            };
            if self.outstanding <= keep_f && self.outstanding_writes <= keep_w {
                break;
            }
            match self.done_rx.recv() {
                Ok(done) => self.apply_done(done, pool),
                Err(_) => return Err(Error::Runtime("swap worker thread died".into())),
            }
        }
        if let Some(err) = first_err {
            // Error path: park the pump, force-restore any carried entry
            // (secondary errors lose to the original), and leave the
            // engine coherent for whoever inspects it after the failure.
            self.issue_cursor = self.by_prefetch.len();
            for k in 0..self.by_prefetch.len() {
                let idx = self.by_prefetch[k];
                if self.entries[idx].wrap && self.evicted[idx] && !self.restored[idx] {
                    let _ = self.finish_prefetch(idx, pool, None);
                }
            }
            while self.outstanding > 0 || self.outstanding_writes > 0 {
                match self.done_rx.recv() {
                    Ok(done) => self.apply_done(done, pool),
                    Err(_) => break,
                }
            }
            self.staged.clear();
            // A non-wrap entry whose restore failed (or whose staged
            // fetch was just discarded) still holds the pool claim from
            // its landed eviction; the next iteration re-evicts the same
            // region and would double-release. Its data is transient —
            // the next iteration regenerates it before any read — so
            // drop the claim now (debug poison stays visible until the
            // regenerating write). Wrap entries keep theirs: the store
            // copy is the live weights, and the carried-state path in
            // `begin_iteration`/`finish_prefetch` restores it. A
            // write-failed entry never released (the release rides the
            // write's success), so it is excluded.
            for idx in 0..self.entries.len() {
                if !self.entries[idx].wrap
                    && self.evicted[idx]
                    && !self.restored[idx]
                    && !self.write_failed.contains_key(&idx)
                {
                    pool.reacquire(self.entries[idx].region, &[]);
                    self.restored[idx] = true;
                }
            }
            return Err(err);
        }
        if pipelined {
            self.staged.retain(|&i, _| self.entries[i].wrap);
            self.issue_cursor = 0;
            self.pump_issues();
        } else {
            self.staged.clear();
        }
        if let Some(&idx) = self.write_failed.keys().next() {
            return Err(self.write_failed.remove(&idx).unwrap());
        }
        if let Some((t0, stall0)) = self.iter_start.take() {
            let iter_ns = t0.elapsed().as_nanos() as u64;
            let stall_in_iter = self.stats.stall_ns() - stall0;
            let compute_ns = iter_ns.saturating_sub(stall_in_iter);
            let (warmup_iters, alpha) = match &self.calibration {
                Some(c) => (c.warmup_iters, c.ewma_alpha),
                None => return Ok(()),
            };
            if self.warmup_done < warmup_iters {
                // warmup: average, then anchor the EWMA on the mean
                self.warmup_compute_ns += compute_ns;
                self.warmup_done += 1;
                if self.warmup_done >= warmup_iters {
                    self.compute_observed_ns =
                        self.warmup_compute_ns as f64 / self.warmup_done.max(1) as f64;
                    self.recalibrate();
                }
            } else {
                ewma_update(&mut self.compute_observed_ns, compute_ns as f64, alpha);
                self.recalibrate();
            }
        }
        Ok(())
    }

    /// Observed-feedback refinement (Calibrated), run after every full
    /// iteration past warmup: rescale the per-EO cost model to the
    /// observed compute time (relative shape from analysis, absolute
    /// scale from measurement), re-derive every entry's read lead from
    /// its *observed* fetch EWMA (falling back to the compile-time
    /// probe until a sample exists) within its safe bound, re-sort the
    /// barrier order when anything moved, and grow the in-flight depth
    /// to the observed traffic-over-compute ratio — eviction traffic
    /// included: both workers serialize on the store, so write time the
    /// evict EWMAs measure delays fetches just like fetch time does.
    /// (Write *leads* stay compile-time: the write barrier is
    /// event-driven off the placed layout, so re-deriving them at
    /// runtime would change nothing.) Runs between iterations, when no
    /// per-iteration state is live.
    fn recalibrate(&mut self) {
        let Some(cal) = self.calibration.as_mut() else { return };
        if self.compute_observed_ns > 0.0 {
            cal.cost.rescale_to_iteration_ns(self.compute_observed_ns);
        }
        let mut transfer_total = 0.0f64;
        let mut changed = false;
        for (k, e) in self.entries.iter_mut().enumerate() {
            let est = if self.fetch_observed_ns[k] > 0.0 {
                self.fetch_observed_ns[k]
            } else {
                cal.store.fetch_ns(e.region.len * 4)
            };
            transfer_total += est;
            transfer_total += if self.evict_observed_ns[k] > 0.0 {
                self.evict_observed_ns[k]
            } else {
                cal.store.evict_ns(e.region.len * 4)
            };
            let derived = if e.wrap {
                wrap_lead_for_ns(est, e.evict_after, e.prefetch_before, &cal.cost)
            } else {
                lead_for_ns(est, e.evict_after, e.prefetch_before, &cal.cost)
            };
            let derived = derived.clamp(1, e.max_lead);
            if derived != e.lead {
                e.lead = derived;
                e.due = e.prefetch_before.saturating_sub(e.lead);
                changed = true;
            }
        }
        if changed {
            self.by_prefetch
                .sort_by_key(|&i| (self.entries[i].due, self.entries[i].prefetch_before, i));
        }
        // depth: observed transfer traffic over observed compute, grown
        // only (adapt_depth owns the stall-reactive boosts; shrinking
        // mid-epoch would fight it)
        let derived = (transfer_total / cal.cost.total_ns().max(1.0)).ceil() as usize;
        let derived = derived.clamp(PREFETCH_DEPTH, self.entries.len().max(PREFETCH_DEPTH));
        self.depth = self.depth.max(derived);
    }

    /// Full drain: complete every carried boundary transfer and restore
    /// every carried wrap entry, leaving the engine with all data in
    /// primary memory and nothing in flight. Mandatory before anything
    /// that must observe a quiescent pool — the end of a run (weights
    /// are read out), `compact_pool` (regions move), and checkpoint /
    /// state export (the pool bytes are the source of truth). A no-op
    /// when nothing is carried, so callers may invoke it defensively.
    pub fn quiesce(&mut self, pool: &MemoryPool) -> Result<()> {
        while self.outstanding > 0 || self.outstanding_writes > 0 {
            match self.done_rx.recv() {
                Ok(done) => self.apply_done(done, pool),
                Err(_) => return Err(Error::Runtime("swap worker thread died".into())),
            }
        }
        let mut first_err: Option<Error> = None;
        for k in 0..self.by_prefetch.len() {
            let idx = self.by_prefetch[k];
            if self.entries[idx].wrap && self.evicted[idx] && !self.restored[idx] {
                if let Err(err) = self.finish_prefetch(idx, pool, None) {
                    first_err.get_or_insert(err);
                }
            }
        }
        self.staged.clear();
        if let Some(err) = first_err {
            return Err(err);
        }
        if let Some(&idx) = self.write_failed.keys().next() {
            return Err(self.write_failed.remove(&idx).unwrap());
        }
        Ok(())
    }

    /// Whether any boundary transfer or carried eviction is live —
    /// diagnostics and tests ("did the pipeline actually carry state?").
    pub fn has_carried_state(&self) -> bool {
        self.outstanding > 0
            || self.outstanding_writes > 0
            || !self.staged.is_empty()
            || self
                .entries
                .iter()
                .enumerate()
                .any(|(i, e)| e.wrap && self.evicted[i] && !self.restored[i])
    }

    /// Disable cross-iteration pipelining: `end_iteration` drains wrap
    /// transfers like everything else and the pump never issues their
    /// fetches, so every boundary restore runs inline at its due EO
    /// (accrued as `boundary_stall_ns`). Bitwise identical either way —
    /// the switch exists so benches can show what the pipeline takes off
    /// the boundary. Flip only at a quiescent point (before the first
    /// iteration, or after [`SwapExec::quiesce`]).
    pub fn set_boundary_drain(&mut self, on: bool) {
        self.boundary_drain = on;
    }

    pub fn boundary_drain(&self) -> bool {
        self.boundary_drain
    }

    /// Epoch-boundary depth adaptation (Calibrated): while stall time
    /// keeps accruing, double the in-flight fetch budget, up to one
    /// fetch per entry. No-op under Fixed tuning.
    pub fn adapt_depth(&mut self) {
        if self.calibration.is_none() {
            return;
        }
        if self.stats.stall_ns() > self.last_stall_ns {
            self.depth = (self.depth * 2).min(self.entries.len().max(PREFETCH_DEPTH));
        }
        self.last_stall_ns = self.stats.stall_ns();
    }

    /// Record an epoch boundary: snapshot the cumulative counters so
    /// per-epoch deltas stay recoverable. The shared training loop
    /// (`session::run_training`) and the bench harness call this right
    /// before `adapt_depth` at every epoch boundary. The snapshots live
    /// in a bounded ring ([`EPOCH_MARK_CAP`] by default): past the cap
    /// the oldest mark is dropped into the delta base, so a fleet
    /// session marking thousands of epochs holds a bounded trajectory
    /// instead of growing without limit.
    pub fn mark_epoch(&mut self) {
        self.epoch_marks.push_back(self.stats);
        while self.epoch_marks.len() > self.epoch_mark_cap {
            self.epoch_base = self.epoch_marks.pop_front().unwrap();
        }
    }

    /// Change the epoch-mark ring capacity (minimum 1). Shrinking below
    /// the current length drops the oldest marks into the delta base
    /// immediately, exactly as if they had aged out.
    pub fn set_epoch_mark_cap(&mut self, cap: usize) {
        self.epoch_mark_cap = cap.max(1);
        while self.epoch_marks.len() > self.epoch_mark_cap {
            self.epoch_base = self.epoch_marks.pop_front().unwrap();
        }
    }

    pub fn epoch_mark_cap(&self) -> usize {
        self.epoch_mark_cap
    }

    /// Per-epoch [`SwapStats`] deltas, one entry per *retained*
    /// `mark_epoch` call — the trajectory view of the counters (a
    /// regression confined to a late epoch is invisible in whole-run
    /// totals dominated by warmup). After the ring wraps, the window
    /// covers the most recent [`SwapExec::epoch_mark_cap`] epochs and
    /// the oldest retained delta is taken against the last dropped mark,
    /// so every delta stays a true single-epoch difference.
    pub fn epoch_stats(&self) -> Vec<SwapStats> {
        let mut prev = self.epoch_base;
        let mut out = Vec::with_capacity(self.epoch_marks.len());
        for mark in &self.epoch_marks {
            out.push(mark.delta(&prev));
            prev = *mark;
        }
        out
    }

    /// Current in-flight fetch budget.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Current completion-barrier lead of an entry (diagnostics, tests).
    pub fn lead_of(&self, entry: usize) -> u32 {
        self.entries[entry].lead
    }

    /// An entry's plan write lead (diagnostics, tests).
    pub fn write_lead_of(&self, entry: usize) -> u32 {
        self.entries[entry].write_lead
    }

    /// An entry's write-completion barrier EO — `u32::MAX` when its gap
    /// is never reclaimed (diagnostics, tests).
    pub fn reclaim_eo_of(&self, entry: usize) -> u32 {
        self.entries[entry].reclaim_eo
    }

    /// A wrap entry's schedule-head write barrier EO — `u32::MAX` when
    /// no head tenant exists or the entry does not wrap (diagnostics,
    /// tests).
    pub fn head_reclaim_eo_of(&self, entry: usize) -> u32 {
        self.entries[entry].head_reclaim_eo
    }

    /// Whether an entry's gap wraps the iteration boundary
    /// (diagnostics, tests).
    pub fn is_wrap(&self, entry: usize) -> bool {
        self.entries[entry].wrap
    }

    /// Number of boundary (wrap) entries in the schedule.
    pub fn n_wrap_entries(&self) -> usize {
        self.entries.iter().filter(|e| e.wrap).count()
    }

    /// An entry's observed fetch EWMA, ns (0 until a background fetch
    /// completed; diagnostics, tests).
    pub fn observed_fetch_ns(&self, entry: usize) -> f64 {
        self.fetch_observed_ns[entry]
    }

    /// An entry's observed evict EWMA, ns (0 until a write ticket
    /// completed; feeds the depth derivation — diagnostics, tests).
    pub fn observed_evict_ns(&self, entry: usize) -> f64 {
        self.evict_observed_ns[entry]
    }

    /// Widest lead currently in effect (post-recalibration — the number
    /// the runtime is actually using, unlike `OffloadPlan::max_lead`).
    pub fn max_lead(&self) -> u32 {
        self.entries.iter().map(|e| e.lead).max().unwrap_or(0)
    }

    /// Apply one worker completion to the engine state. Write
    /// completions release the region (NaN-poisoned in debug) — the
    /// reclaim barrier guarantees no tenant has touched it yet.
    fn apply_done(&mut self, done: Done, pool: &MemoryPool) {
        match done {
            Done::Fetch(i, res, ns) => {
                self.outstanding -= 1;
                if self.entries[i].wrap {
                    self.wrap_fetches_inflight -= 1;
                }
                ewma_update(&mut self.fetch_observed_ns[i], ns as f64, self.ewma_alpha);
                match res {
                    Ok(data) => {
                        self.staged.insert(i, data);
                    }
                    Err(err) => {
                        self.failed.insert(i, err);
                    }
                }
            }
            Done::Write(i, res, ns) => {
                self.outstanding_writes -= 1;
                if self.entries[i].wrap {
                    self.wrap_writes_inflight -= 1;
                }
                ewma_update(&mut self.evict_observed_ns[i], ns as f64, self.ewma_alpha);
                self.evict_done[i] = true;
                match res {
                    Ok(()) => pool.release_gap(self.entries[i].region),
                    Err(err) => {
                        self.write_failed.insert(i, err);
                    }
                }
            }
        }
    }

    /// Block until entry `idx`'s write ticket completes (the write
    /// stall).
    fn wait_write(&mut self, idx: usize, pool: &MemoryPool) -> Result<()> {
        let t0 = Instant::now();
        while !self.evict_done[idx] {
            match self.done_rx.recv() {
                Ok(done) => self.apply_done(done, pool),
                Err(_) => return Err(Error::Runtime("swap evict thread died".into())),
            }
        }
        self.stats.write_stall_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    fn finish_prefetch(&mut self, idx: usize, pool: &MemoryPool, at_eo: Option<u32>) -> Result<()> {
        if self.restored[idx] {
            return Ok(());
        }
        if !self.evicted[idx] {
            // Barrier reached before this entry's eviction ran: with a
            // sane schedule that only happens when the gap never opens
            // this iteration (partial forward pass, end-of-iteration
            // sweep) — the data is still in the pool region and there is
            // nothing to copy. But if the eviction is still *ahead* of
            // the current step, marking the entry restored would let the
            // eviction strand it in the store and the next iteration
            // would silently train on the gap tenant's leftovers; fail
            // loudly instead (regression: schedule-head gap-1 edge).
            // A wrap entry's eviction EO is always at or past its
            // restore barrier's EO (the gap wraps), so for it this arm
            // fires whenever `begin_iteration`'s priming was bypassed —
            // its head window may already belong to a tenant, and the
            // shortcut below would hand those bytes to compute.
            if let Some(eo) = at_eo {
                if self.entries[idx].evict_after >= eo {
                    let e = &self.entries[idx];
                    let cause = if e.wrap {
                        "the boundary cycle was not primed at iteration start"
                    } else {
                        "lead swallows the gap"
                    };
                    return Err(Error::Runtime(format!(
                        "swap schedule inconsistent: prefetch barrier for `{}` fired at \
                         EO {eo} before its eviction at EO {} — {cause} (lead {}, gap \
                         ({}, {}))",
                        e.name, e.evict_after, e.lead, e.evict_after, e.prefetch_before
                    )));
                }
            }
            self.restored[idx] = true;
            return Ok(());
        }
        if let Some(err) = self.write_failed.remove(&idx) {
            return Err(err);
        }
        if let Some(err) = self.failed.remove(&idx) {
            return Err(err);
        }
        // The reacquire below writes this entry's address range: any
        // in-flight eviction of an overlapping entry must land first.
        // (The plan-level barriers already order this, but runtime lead
        // widening — or the end-of-iteration sweep — can move a
        // reacquire ahead of the other entry's reclaim EO.)
        for k in 0..self.overlaps[idx].len() {
            let j = self.overlaps[idx][k];
            if self.evicted[j] && !self.evict_done[j] {
                self.wait_write(j, pool)?;
            }
        }
        if let Some(data) = self.staged.remove(&idx) {
            pool.reacquire(self.entries[idx].region, &data);
            let _ = self.recycle_tx.send(data);
        } else if self.issued[idx] {
            // in flight — wait for the fetch worker (the read stall)
            let t0 = Instant::now();
            loop {
                if let Some(err) = self.failed.remove(&idx) {
                    return Err(err);
                }
                if let Some(data) = self.staged.remove(&idx) {
                    pool.reacquire(self.entries[idx].region, &data);
                    let _ = self.recycle_tx.send(data);
                    let ns = t0.elapsed().as_nanos() as u64;
                    self.stats.read_stall_ns += ns;
                    if self.entries[idx].wrap {
                        self.stats.boundary_stall_ns += ns;
                    }
                    break;
                }
                match self.done_rx.recv() {
                    Ok(done) => self.apply_done(done, pool),
                    Err(_) => {
                        return Err(Error::Runtime("swap prefetch thread died".into()))
                    }
                }
            }
        } else {
            // never issued (gap shorter than the issue horizon): inline.
            // The eviction write must have landed first — full-duplex
            // fetches no longer queue behind writes, so the slot may not
            // exist yet.
            if !self.evict_done[idx] {
                self.wait_write(idx, pool)?;
                if let Some(err) = self.write_failed.remove(&idx) {
                    return Err(err);
                }
            }
            let t0 = Instant::now();
            let region = self.entries[idx].region;
            self.inline_buf.resize(region.len, 0.0);
            self.store.lock().unwrap().get(idx, &mut self.inline_buf)?;
            pool.reacquire(region, &self.inline_buf);
            self.stats.sync_fetches += 1;
            let ns = t0.elapsed().as_nanos() as u64;
            self.stats.read_stall_ns += ns;
            if self.entries[idx].wrap {
                self.stats.boundary_stall_ns += ns;
            }
        }
        self.restored[idx] = true;
        self.residency.insert(self.entries[idx].tensor, Residency::Resident);
        self.stats.prefetches += 1;
        self.stats.bytes_in += (self.entries[idx].region.len * 4) as u64;
        if self.entries[idx].wrap {
            // the carried boundary cycle is complete — reset the
            // eviction flags so this iteration's own eviction at
            // `evict_after` starts a fresh cycle
            self.evicted[idx] = false;
            self.evict_done[idx] = false;
            self.issued[idx] = false;
        }
        self.pump_issues();
        Ok(())
    }

    fn drain_completions(&mut self, pool: &MemoryPool) {
        while let Ok(done) = self.done_rx.try_recv() {
            self.apply_done(done, pool);
        }
    }

    /// Issue background fetches in barrier-deadline (`due`) order, up to
    /// the current depth in flight.
    ///
    /// An entry whose eviction write has not landed is not yet issuable
    /// — its store slot may not exist. It used to block the whole queue
    /// (head-of-line): one slow eviction write starved every
    /// later-deadline entry of its background fetch, turning them into
    /// inline sync fetches. Instead the pump *skips over* such entries,
    /// bounded by the in-flight depth (never more than `depth` pending
    /// entries deep), and never reorders two *issuable* entries — the
    /// scan stays in deadline order, so ready fetches still issue
    /// earliest-barrier first. The cursor itself only advances past
    /// consumed entries, so a skipped entry is re-examined on every
    /// pump until it becomes issuable.
    fn pump_issues(&mut self) {
        let mut k = self.issue_cursor;
        let mut pending_skipped = 0usize;
        while self.outstanding < self.depth && k < self.by_prefetch.len() {
            let idx = self.by_prefetch[k];
            // consumed for this cycle: nothing left to issue here. A wrap
            // entry whose eviction has not happened yet (data resident)
            // is consumed too — its eviction rewinds the cursor — as is
            // any wrap entry under the boundary drain, whose restore
            // always runs inline at the sweep.
            let consumed = self.restored[idx]
                || self.issued[idx]
                || (self.entries[idx].wrap && (self.boundary_drain || !self.evicted[idx]));
            if consumed {
                if k == self.issue_cursor {
                    self.issue_cursor += 1;
                }
                k += 1;
                continue;
            }
            if !self.evict_done[idx] || self.write_failed.contains_key(&idx) {
                pending_skipped += 1;
                if pending_skipped >= self.depth {
                    break;
                }
                k += 1;
                continue;
            }
            if self.fetch_tx.send(Req::Fetch(idx)).is_err() {
                break; // worker gone; the sync fallback will surface it
            }
            self.issued[idx] = true;
            if self.entries[idx].wrap {
                self.wrap_fetches_inflight += 1;
            }
            self.residency.insert(self.entries[idx].tensor, Residency::Fetching);
            self.outstanding += 1;
            if k == self.issue_cursor {
                self.issue_cursor += 1;
            }
            k += 1;
        }
    }

    /// Test hook: move one entry's prefetch deadline, desynchronizing the
    /// schedule from the plan — the residency guard (or the barrier
    /// inconsistency check) must then trip.
    #[doc(hidden)]
    pub fn delay_prefetch_for_test(&mut self, entry: usize, new_prefetch_before: u32) {
        let e = &mut self.entries[entry];
        e.prefetch_before = new_prefetch_before;
        e.due = new_prefetch_before.saturating_sub(e.lead);
        self.by_prefetch
            .sort_by_key(|&i| (self.entries[i].due, self.entries[i].prefetch_before, i));
    }

    /// Name of an entry's tensor (diagnostics, tests).
    pub fn entry_tensor_name(&self, entry: usize) -> &str {
        &self.entries[entry].name
    }

    /// An entry's `(evict_after, prefetch_before)` gap (diagnostics,
    /// tests).
    pub fn entry_gap(&self, entry: usize) -> (u32, u32) {
        (self.entries[entry].evict_after, self.entries[entry].prefetch_before)
    }
}

impl Drop for SwapExec {
    fn drop(&mut self) {
        // Stop lands behind any queued tickets (the channels are FIFO),
        // so both workers drain their pending transfers — which may
        // still read the pool — before exiting; the joins below are the
        // teardown write barrier. `Executor` declares `swap` before
        // `pool` and standalone users drop the engine before its pool,
        // so the spans stay valid until here.
        let _ = self.fetch_tx.send(Req::Stop);
        let _ = self.evict_tx.send(Req::Stop);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Slot audit invariant: teardown leaves the store empty (the
        // calibration probes already freed theirs). Newest-first so the
        // FileStore rolls its end offset back.
        if let Ok(mut store) = self.store.lock() {
            for i in (0..self.entries.len()).rev() {
                store.free(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::offload::{OffloadEntry, PREFETCH_LEAD, WRITE_LEAD};
    use crate::runtime::store::HostStore;
    use crate::tensor::{CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable};

    fn table_one(eos: &[u32], len: usize) -> TensorTable {
        let mut t = TensorTable::new();
        let id = t
            .request("a", TensorDim::vec(1, len), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .unwrap();
        for &e in eos {
            t.add_eo(id, e, Lifespan::FORWARD);
        }
        t.finish_orders();
        t.get_mut(id).region = Some(Region { offset: 0, len });
        t
    }

    fn plan_one(evict_after: u32, prefetch_before: u32, lead: u32, bytes: usize) -> OffloadPlan {
        OffloadPlan {
            entries: vec![OffloadEntry {
                tensor: 0,
                name: "a".into(),
                bytes,
                evict_after,
                prefetch_before,
                lead,
                write_lead: WRITE_LEAD,
                wrap: false,
            }],
            primary_peak_bytes: bytes,
            swap_bytes_per_iter: 2 * bytes,
            fits: true,
            prefetch_depth: PREFETCH_DEPTH,
        }
    }

    /// Regression (schedule-head edge): a lead that swallows the gap
    /// would fire the completion barrier before the eviction — the
    /// entry would be judged resident while its fetch was never issued
    /// and the next iteration would silently train on garbage. The
    /// constructor must reject it for any lead, including the fixed
    /// default on a (corrupted) 1-EO gap.
    #[test]
    fn lead_swallowing_gap_is_rejected() {
        // gap of exactly 1 EO with the default lead 1
        let t = table_one(&[0, 1, 2], 16);
        let err = SwapExec::new(&t, &plan_one(0, 1, PREFETCH_LEAD, 64), Box::new(HostStore::new()), None)
            .err()
            .expect("gap-1 entry must be rejected");
        assert!(err.to_string().contains("swallowing"), "{err}");

        // calibrated-style wide lead on a wide gap
        let t = table_one(&[0, 10], 16);
        let err = SwapExec::new(&t, &plan_one(0, 10, 10, 64), Box::new(HostStore::new()), None)
            .err()
            .expect("gap-swallowing lead must be rejected");
        assert!(err.to_string().contains("swallowing"), "{err}");

        // the widest admissible lead still builds
        assert!(SwapExec::new(&t, &plan_one(0, 10, 9, 64), Box::new(HostStore::new()), None).is_ok());
    }

    /// The write-side twin: a write lead whose extension meets the read
    /// reservation inside the gap must be rejected, and the widest
    /// admissible pair must still build.
    #[test]
    fn write_lead_swallowing_gap_is_rejected() {
        let t = table_one(&[0, 10], 16);
        let mut plan = plan_one(0, 10, 4, 64);
        plan.entries[0].write_lead = 6; // 0 + 4 + 6 >= 10
        let err = SwapExec::new(&t, &plan, Box::new(HostStore::new()), None)
            .err()
            .expect("write lead swallowing the gap must be rejected");
        assert!(err.to_string().contains("write lead"), "{err}");

        plan.entries[0].write_lead = 5; // 0 + 4 + 5 < 10
        let sw = SwapExec::new(&t, &plan, Box::new(HostStore::new()), None).unwrap();
        assert_eq!(sw.write_lead_of(0), 5);
        // a lone tensor's gap is never reclaimed
        assert_eq!(sw.reclaim_eo_of(0), u32::MAX);
    }

    /// The barrier order follows per-entry due EOs, not raw
    /// `prefetch_before`: a big entry with a wide lead must complete
    /// before a small entry whose deadline is nominally earlier.
    #[test]
    fn barrier_order_uses_due_not_prefetch_before() {
        let mut t = TensorTable::new();
        for (name, eos) in [("a", vec![0u32, 20]), ("b", vec![1u32, 12])] {
            let id = t
                .request(name, TensorDim::vec(1, 8), TensorRole::Activation, CreateMode::Create, Initializer::None)
                .unwrap();
            for e in eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        t.get_mut(0).region = Some(Region { offset: 0, len: 8 });
        t.get_mut(1).region = Some(Region { offset: 8, len: 8 });
        let mut plan = plan_one(0, 20, 12, 32); // a: due at EO 8
        plan.entries.push(OffloadEntry {
            tensor: 1,
            name: "b".into(),
            bytes: 32,
            evict_after: 1,
            prefetch_before: 12, // due at EO 11 — later than a's despite earlier deadline
            lead: 1,
            write_lead: WRITE_LEAD,
            wrap: false,
        });
        let sw = SwapExec::new(&t, &plan, Box::new(HostStore::new()), None).unwrap();
        assert_eq!(sw.entry_tensor_name(sw.by_prefetch[0]), "a");
        assert_eq!(sw.entry_tensor_name(sw.by_prefetch[1]), "b");
    }

    /// The reclaim barrier EO comes from the placed table: a tenant
    /// sharing the address range sets it to its first reserved EO; with
    /// disjoint placement the gap is never reclaimed.
    #[test]
    fn reclaim_eo_follows_gap_tenant_placement() {
        let mut t = TensorTable::new();
        for (name, eos) in [("a", vec![0u32, 10]), ("b", vec![3u32, 5])] {
            let id = t
                .request(name, TensorDim::vec(1, 8), TensorRole::Activation, CreateMode::Create, Initializer::None)
                .unwrap();
            for e in eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        // b shares a's address range during a's gap
        t.get_mut(0).region = Some(Region { offset: 0, len: 8 });
        t.get_mut(1).region = Some(Region { offset: 0, len: 8 });
        let sw = SwapExec::new(&t, &plan_one(0, 10, 1, 32), Box::new(HostStore::new()), None).unwrap();
        assert_eq!(sw.reclaim_eo_of(0), 3, "tenant's first use is the write barrier");

        // disjoint placement: never reclaimed
        t.get_mut(1).region = Some(Region { offset: 8, len: 8 });
        let sw = SwapExec::new(&t, &plan_one(0, 10, 1, 32), Box::new(HostStore::new()), None).unwrap();
        assert_eq!(sw.reclaim_eo_of(0), u32::MAX);
    }

    /// Regression (unbounded epoch marks): `mark_epoch` used to push
    /// forever — a fleet session running thousands of epochs leaked a
    /// snapshot per epoch. The ring caps retention, and the per-epoch
    /// deltas stay correct across the wrap: the oldest retained delta is
    /// taken against the last *dropped* mark, not zero.
    #[test]
    fn epoch_marks_are_ring_capped_with_correct_deltas() {
        let t = table_one(&[0, 10], 16);
        let mut sw =
            SwapExec::new(&t, &plan_one(0, 10, 1, 64), Box::new(HostStore::new()), None).unwrap();
        sw.set_epoch_mark_cap(4);
        for i in 1..=10u64 {
            // monotone counter: epoch i ends with `prefetches == i²`
            sw.stats.prefetches = i * i;
            sw.mark_epoch();
        }
        let deltas = sw.epoch_stats();
        assert_eq!(deltas.len(), 4, "ring keeps only the newest cap marks");
        // epochs 7..=10 survive; delta of epoch i is i² − (i−1)², even
        // for the oldest retained one (its base is the dropped epoch 6)
        let expect: Vec<u64> = (7..=10u64).map(|i| i * i - (i - 1) * (i - 1)).collect();
        let got: Vec<u64> = deltas.iter().map(|d| d.prefetches).collect();
        assert_eq!(got, expect);

        // shrinking the cap drops the oldest marks immediately, keeping
        // the base in sync
        sw.set_epoch_mark_cap(2);
        let deltas = sw.epoch_stats();
        assert_eq!(deltas.len(), 2);
        let got: Vec<u64> = deltas.iter().map(|d| d.prefetches).collect();
        assert_eq!(got, vec![81 - 64, 100 - 81], "base moved to epoch 8's mark");
    }
}
