//! Proactive swap runtime: executes an [`OffloadPlan`] during training.
//!
//! The paper's stated future work — "we can swap in and out proactively
//! in background" — falls out of Algorithm 1's execution orders: every
//! tensor access point is known before training starts, so eviction and
//! prefetch are *scheduled*, not demand-paged. The protocol, per training
//! step at execution order `e`:
//!
//! 1. **pre-step** — complete every prefetch whose barrier EO
//!    (`prefetch_before − lead`, per entry) has arrived: copy the staged
//!    bytes back into the tensor's pool region
//!    ([`MemoryPool::reacquire`]). If the background fetch has not
//!    finished, block (counted as swap stall); if it was never issued
//!    (gap shorter than the issue horizon), fetch inline.
//! 2. **residency guard** — no offloaded tensor may be `Evicted` or
//!    `Fetching` at one of its own use EOs. Any violation means the plan
//!    and the runtime have drifted; the step fails loudly instead of
//!    computing on poisoned data.
//! 3. **execute the layer phase** (the executor's job).
//! 4. **post-step** — evict every entry with `evict_after == e`: copy the
//!    region to the [`SecondaryStore`], release it
//!    ([`MemoryPool::release_gap`]), then top up the background prefetch
//!    queue (deadline-ordered, up to the current depth in flight).
//!
//! Leads and depth come from the offload plan: the PR-1 constants under
//! `SwapTuning::Fixed` (1-EO lead, depth [`PREFETCH_DEPTH`]), or
//! per-entry values derived from measured store bandwidth under
//! `SwapTuning::Calibrated` (`runtime/calibrate.rs`). Calibrated runs
//! keep refining at runtime: warmup iterations are timed to rescale the
//! per-EO cost model (leads then re-derive within each entry's safe
//! bound), and [`SwapExec::adapt_depth`] grows the in-flight window at
//! epoch boundaries while stall telemetry is non-zero. None of this
//! affects results: tuning only moves *when* copies happen, and every
//! copy stays on the training thread at a deterministic step boundary.
//!
//! The background thread only ever touches the store and its own staging
//! buffers — never the pool — so the pool stays single-threaded; the main
//! thread performs every region copy at a deterministic point in the step
//! order, which is what keeps swapped and unswapped training bitwise
//! identical (see `rust/tests/swap_equivalence.rs`).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::planner::offload::{live_intervals, OffloadPlan};
use crate::planner::pool::MemoryPool;
use crate::tensor::{Region, Residency, TensorId, TensorTable};

use super::calibrate::{lead_for, SwapCalibration};
use super::store::SecondaryStore;

pub use crate::planner::offload::PREFETCH_DEPTH;

/// One scheduled gap of one tensor (a tensor with several idle gaps per
/// iteration has one entry per gap).
struct SwapEntry {
    tensor: TensorId,
    name: String,
    region: Region,
    evict_after: u32,
    prefetch_before: u32,
    /// Completion-barrier lead: the reacquire happens at the pre-step of
    /// EO `prefetch_before − lead`.
    lead: u32,
    /// Barrier EO (`prefetch_before − lead`, saturated).
    due: u32,
    /// Widest lead whose early reacquire cannot collide with any other
    /// tensor placed on an overlapping address range — the bound for
    /// runtime re-derivation (plan leads are ≤ this by validation).
    max_lead: u32,
}

/// Use points of an offloaded root tensor, for the residency guard.
struct RootInfo {
    name: String,
    eos: Vec<u32>,
}

enum Req {
    Fetch(usize),
    Stop,
}

/// Cumulative swap-runtime counters (whole run, not per iteration).
#[derive(Clone, Copy, Debug, Default)]
pub struct SwapStats {
    pub evictions: u64,
    pub prefetches: u64,
    /// Prefetches that had to run inline on the training thread because
    /// the gap was shorter than the issue horizon.
    pub sync_fetches: u64,
    pub bytes_out: u64,
    pub bytes_in: u64,
    /// Wall time the training thread spent waiting on swap-ins.
    pub stall_ns: u64,
}

impl SwapStats {
    pub fn stall_ms(&self) -> f64 {
        self.stall_ns as f64 / 1e6
    }
}

/// Executable swap schedule bound to one compiled model's pool layout.
pub struct SwapExec {
    entries: Vec<SwapEntry>,
    plan: OffloadPlan,
    /// EO → entries to evict right after the step at that EO.
    evict_at: HashMap<u32, Vec<usize>>,
    /// Entry indices sorted by barrier EO (`due`) — both the completion
    /// barrier order and the background issue order.
    by_prefetch: Vec<usize>,
    roots: HashMap<TensorId, RootInfo>,
    residency: HashMap<TensorId, Residency>,
    // per-iteration entry state
    evicted: Vec<bool>,
    issued: Vec<bool>,
    restored: Vec<bool>,
    staged: HashMap<usize, Vec<f32>>,
    failed: HashMap<usize, Error>,
    next_due: usize,
    issue_cursor: usize,
    outstanding: usize,
    store: Arc<Mutex<Box<dyn SecondaryStore>>>,
    store_kind: &'static str,
    req_tx: Sender<Req>,
    done_rx: Receiver<(usize, Result<Vec<f32>>)>,
    /// Staging buffers handed back to the worker for reuse, keeping the
    /// steady-state prefetch path allocation-free.
    recycle_tx: Sender<Vec<f32>>,
    worker: Option<JoinHandle<()>>,
    /// Current in-flight fetch budget (plan's initial depth; grows via
    /// [`SwapExec::adapt_depth`] under calibrated tuning).
    depth: usize,
    /// Calibration state for runtime refinement (None under Fixed).
    calibration: Option<SwapCalibration>,
    /// Warmup timing: iterations measured so far, their total wall ns,
    /// and the stall ns accrued *inside* them (untimed forward passes
    /// also accrue stalls, which must not skew the compute estimate).
    warmup_done: u64,
    warmup_ns: u64,
    warmup_stall_ns: u64,
    /// Wall-clock start and `stats.stall_ns` snapshot of a timed
    /// (warmup) iteration.
    iter_start: Option<(Instant, u64)>,
    /// Stall counter snapshot at the last `adapt_depth` call.
    last_stall_ns: u64,
    pub stats: SwapStats,
}

impl SwapExec {
    /// Build the schedule from a planned table (regions assigned by the
    /// gap-aware planner) and spawn the background prefetcher.
    ///
    /// Every entry's lead must leave the completion barrier strictly
    /// after the eviction (`prefetch_before > evict_after + lead`). A
    /// lead that swallows the gap would fire the barrier before the gap
    /// opens: the entry would be judged "still resident" while its fetch
    /// was never issued, and from the *next* iteration on training would
    /// silently read whatever the gap tenant left in the region — the
    /// schedule-head edge this constructor now rejects loudly.
    pub fn new(
        table: &TensorTable,
        plan: &OffloadPlan,
        store: Box<dyn SecondaryStore>,
        calibration: Option<SwapCalibration>,
    ) -> Result<SwapExec> {
        let mut entries = Vec::with_capacity(plan.entries.len());
        let mut roots: HashMap<TensorId, RootInfo> = HashMap::new();
        let mut residency: HashMap<TensorId, Residency> = HashMap::new();
        for e in &plan.entries {
            let s = table.get(e.tensor);
            if e.evict_after >= e.prefetch_before {
                return Err(Error::planner(format!(
                    "offload entry for `{}` has an empty gap ({} >= {})",
                    s.name, e.evict_after, e.prefetch_before
                )));
            }
            if e.prefetch_before <= e.evict_after.saturating_add(e.lead) {
                return Err(Error::planner(format!(
                    "offload entry for `{}` has lead {} swallowing its gap ({}, {}): \
                     the prefetch barrier would fire before the eviction",
                    s.name, e.lead, e.evict_after, e.prefetch_before
                )));
            }
            let region = s.region.ok_or_else(|| {
                Error::planner(format!("offloaded tensor `{}` has no region", s.name))
            })?;
            entries.push(SwapEntry {
                tensor: e.tensor,
                name: s.name.clone(),
                region,
                evict_after: e.evict_after,
                prefetch_before: e.prefetch_before,
                lead: e.lead,
                due: e.prefetch_before.saturating_sub(e.lead),
                max_lead: e.lead, // widened below from the placed table
            });
            roots
                .entry(e.tensor)
                .or_insert_with(|| RootInfo { name: s.name.clone(), eos: s.eos.clone() });
            residency.insert(e.tensor, Residency::Resident);
        }
        // Per-entry safe widening bound: the earliest EO at which the
        // entry's region is free of every *other* tensor placed on an
        // overlapping address range (their reserved intervals under the
        // plan's own leads). Runtime re-derivation may widen a lead up
        // to this without colliding with a gap tenant.
        let leads = plan.lead_map();
        let offloaded: std::collections::HashSet<TensorId> =
            plan.entries.iter().map(|e| e.tensor).collect();
        for entry in &mut entries {
            let mut earliest = entry.evict_after + 1;
            for s in table.iter() {
                if s.merged_into.is_some() || s.eos.is_empty() || s.id == entry.tensor {
                    continue;
                }
                let Some(r) = s.region else { continue };
                let overlap = r.offset < entry.region.end() && entry.region.offset < r.end();
                if !overlap {
                    continue;
                }
                for (_, z) in live_intervals(s, offloaded.contains(&s.id).then_some(&leads)) {
                    if z < entry.prefetch_before {
                        earliest = earliest.max(z + 1);
                    }
                }
            }
            entry.max_lead = (entry.prefetch_before - earliest).max(entry.lead);
        }
        let n = entries.len();
        let mut evict_at: HashMap<u32, Vec<usize>> = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            evict_at.entry(e.evict_after).or_default().push(i);
        }
        let mut by_prefetch: Vec<usize> = (0..n).collect();
        by_prefetch.sort_by_key(|&i| (entries[i].due, entries[i].prefetch_before, i));

        let store_kind = store.kind();
        let store = Arc::new(Mutex::new(store));
        let (req_tx, req_rx) = channel::<Req>();
        let (done_tx, done_rx) = channel::<(usize, Result<Vec<f32>>)>();
        let (recycle_tx, recycle_rx) = channel::<Vec<f32>>();
        let lens: Vec<usize> = entries.iter().map(|e| e.region.len).collect();
        let wstore = Arc::clone(&store);
        let worker = std::thread::Builder::new()
            .name("nntrainer-prefetch".into())
            .spawn(move || {
                while let Ok(req) = req_rx.recv() {
                    match req {
                        Req::Fetch(i) => {
                            // reuse a returned staging buffer when one is
                            // available — steady state allocates nothing
                            let mut buf = recycle_rx.try_recv().unwrap_or_default();
                            if buf.len() != lens[i] {
                                buf.resize(lens[i], 0.0);
                            }
                            let res = wstore.lock().unwrap().get(i, &mut buf).map(|()| buf);
                            if done_tx.send((i, res)).is_err() {
                                break;
                            }
                        }
                        Req::Stop => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn prefetch thread: {e}")))?;

        Ok(SwapExec {
            entries,
            plan: plan.clone(),
            evict_at,
            by_prefetch,
            roots,
            residency,
            evicted: vec![false; n],
            issued: vec![false; n],
            restored: vec![false; n],
            staged: HashMap::new(),
            failed: HashMap::new(),
            next_due: 0,
            issue_cursor: 0,
            outstanding: 0,
            store,
            store_kind,
            req_tx,
            done_rx,
            recycle_tx,
            worker: Some(worker),
            depth: plan.prefetch_depth.max(PREFETCH_DEPTH),
            calibration,
            warmup_done: 0,
            warmup_ns: 0,
            warmup_stall_ns: 0,
            iter_start: None,
            last_stall_ns: 0,
            stats: SwapStats::default(),
        })
    }

    pub fn plan(&self) -> &OffloadPlan {
        &self.plan
    }

    pub fn store_kind(&self) -> &'static str {
        self.store_kind
    }

    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    pub fn residency_of(&self, root: TensorId) -> Option<Residency> {
        self.residency.get(&root).copied()
    }

    /// Reset per-iteration state. Every entry must have been restored by
    /// the previous iteration's `end_iteration`. `full_schedule` is true
    /// for training iterations (every EO runs): only those are timed as
    /// calibration warmup — a forward-only pass covers a fraction of the
    /// schedule and would rescale the cost model to nonsense.
    pub fn begin_iteration(&mut self, full_schedule: bool) -> Result<()> {
        if self.outstanding != 0 || !self.staged.is_empty() {
            return Err(Error::Runtime(
                "swap runtime: stale prefetches at iteration start".into(),
            ));
        }
        self.evicted.iter_mut().for_each(|v| *v = false);
        self.issued.iter_mut().for_each(|v| *v = false);
        self.restored.iter_mut().for_each(|v| *v = false);
        self.residency.values_mut().for_each(|r| *r = Residency::Resident);
        self.failed.clear();
        self.next_due = 0;
        self.issue_cursor = 0;
        // warmup iterations are timed to rescale the calibrated cost model
        self.iter_start = match &self.calibration {
            Some(cal) if full_schedule && self.warmup_done < cal.warmup_iters => {
                Some((Instant::now(), self.stats.stall_ns))
            }
            _ => None,
        };
        Ok(())
    }

    /// Complete every prefetch whose barrier EO is at or before `eo`.
    pub fn pre_step(&mut self, eo: u32, pool: &MemoryPool) -> Result<()> {
        while self.next_due < self.by_prefetch.len() {
            let idx = self.by_prefetch[self.next_due];
            if self.entries[idx].due > eo {
                break;
            }
            self.finish_prefetch(idx, pool, Some(eo))?;
            self.next_due += 1;
        }
        Ok(())
    }

    /// The residency guard: no offloaded tensor may be away from primary
    /// memory at one of its own use EOs. Catches plan/runtime drift (and
    /// deliberately corrupted plans) before a layer computes on poison.
    pub fn check_residency(&self, eo: u32) -> Result<()> {
        for (id, info) in &self.roots {
            let state = self.residency.get(id).copied().unwrap_or(Residency::Resident);
            if state != Residency::Resident && info.eos.binary_search(&eo).is_ok() {
                return Err(Error::Runtime(format!(
                    "residency violation: `{}` is {:?} at EO {eo}, one of its use points — \
                     the offload plan and the swap runtime have drifted",
                    info.name, state
                )));
            }
        }
        Ok(())
    }

    /// Evict entries whose gap starts after the step at `eo`, then top up
    /// the background prefetch queue.
    pub fn post_step(&mut self, eo: u32, pool: &MemoryPool) -> Result<()> {
        if let Some(idxs) = self.evict_at.get(&eo) {
            for &idx in idxs {
                let e = &self.entries[idx];
                self.store.lock().unwrap().put(idx, pool.view(e.region))?;
                pool.release_gap(e.region);
                self.evicted[idx] = true;
                self.residency.insert(e.tensor, Residency::Evicted);
                self.stats.evictions += 1;
                self.stats.bytes_out += (e.region.len * 4) as u64;
            }
        }
        self.drain_completions();
        self.pump_issues();
        Ok(())
    }

    /// Restore everything still out (e.g. a final gap whose prefetch EO
    /// has no step in this schedule) so weights/outputs can be read and
    /// the next iteration starts clean.
    pub fn end_iteration(&mut self, pool: &MemoryPool) -> Result<()> {
        for k in 0..self.by_prefetch.len() {
            let idx = self.by_prefetch[k];
            if !self.restored[idx] {
                self.finish_prefetch(idx, pool, None)?;
            }
        }
        self.next_due = self.by_prefetch.len();
        while self.outstanding > 0 {
            match self.done_rx.recv() {
                Ok((i, res)) => {
                    self.outstanding -= 1;
                    if let Ok(data) = res {
                        self.staged.insert(i, data);
                    }
                }
                Err(_) => return Err(Error::Runtime("swap prefetch thread died".into())),
            }
        }
        self.staged.clear();
        if let Some((t0, stall0)) = self.iter_start.take() {
            self.warmup_ns += t0.elapsed().as_nanos() as u64;
            self.warmup_stall_ns += self.stats.stall_ns - stall0;
            self.warmup_done += 1;
            if self
                .calibration
                .as_ref()
                .is_some_and(|c| self.warmup_done >= c.warmup_iters)
            {
                self.recalibrate_leads();
            }
        }
        Ok(())
    }

    /// Warmup refinement (Calibrated): rescale the per-EO cost model so
    /// the estimated schedule cost matches the measured iteration wall
    /// time (minus counted stalls), then re-derive every entry's lead
    /// within its safe bound and re-sort the barrier order. Runs between
    /// iterations, when no per-iteration state is live.
    fn recalibrate_leads(&mut self) {
        let Some(cal) = self.calibration.as_mut() else { return };
        let compute_ns = self.warmup_ns.saturating_sub(self.warmup_stall_ns) as f64
            / self.warmup_done.max(1) as f64;
        cal.cost.rescale_to_iteration_ns(compute_ns);
        for e in &mut self.entries {
            let derived = lead_for(
                e.region.len * 4,
                e.evict_after,
                e.prefetch_before,
                &cal.store,
                &cal.cost,
            );
            e.lead = derived.clamp(1, e.max_lead);
            e.due = e.prefetch_before.saturating_sub(e.lead);
        }
        self.by_prefetch
            .sort_by_key(|&i| (self.entries[i].due, self.entries[i].prefetch_before, i));
    }

    /// Epoch-boundary depth adaptation (Calibrated): while stall time
    /// keeps accruing, double the in-flight fetch budget, up to one
    /// fetch per entry. No-op under Fixed tuning.
    pub fn adapt_depth(&mut self) {
        if self.calibration.is_none() {
            return;
        }
        if self.stats.stall_ns > self.last_stall_ns {
            self.depth = (self.depth * 2).min(self.entries.len().max(PREFETCH_DEPTH));
        }
        self.last_stall_ns = self.stats.stall_ns;
    }

    /// Current in-flight fetch budget.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Current completion-barrier lead of an entry (diagnostics, tests).
    pub fn lead_of(&self, entry: usize) -> u32 {
        self.entries[entry].lead
    }

    /// Widest lead currently in effect (post-recalibration — the number
    /// the runtime is actually using, unlike `OffloadPlan::max_lead`).
    pub fn max_lead(&self) -> u32 {
        self.entries.iter().map(|e| e.lead).max().unwrap_or(0)
    }

    fn finish_prefetch(&mut self, idx: usize, pool: &MemoryPool, at_eo: Option<u32>) -> Result<()> {
        if self.restored[idx] {
            return Ok(());
        }
        if !self.evicted[idx] {
            // Barrier reached before this entry's eviction ran: with a
            // sane schedule that only happens when the gap never opens
            // this iteration (partial forward pass, end-of-iteration
            // sweep) — the data is still in the pool region and there is
            // nothing to copy. But if the eviction is still *ahead* of
            // the current step, marking the entry restored would let the
            // eviction strand it in the store and the next iteration
            // would silently train on the gap tenant's leftovers; fail
            // loudly instead (regression: schedule-head gap-1 edge).
            if let Some(eo) = at_eo {
                if self.entries[idx].evict_after >= eo {
                    let e = &self.entries[idx];
                    return Err(Error::Runtime(format!(
                        "swap schedule inconsistent: prefetch barrier for `{}` fired at \
                         EO {eo} before its eviction at EO {} — lead {} swallows the \
                         gap ({}, {})",
                        e.name, e.evict_after, e.lead, e.evict_after, e.prefetch_before
                    )));
                }
            }
            self.restored[idx] = true;
            return Ok(());
        }
        if let Some(err) = self.failed.remove(&idx) {
            return Err(err);
        }
        if let Some(data) = self.staged.remove(&idx) {
            pool.reacquire(self.entries[idx].region, &data);
            let _ = self.recycle_tx.send(data);
        } else if self.issued[idx] {
            // in flight — wait for the worker (this is the swap stall)
            let t0 = Instant::now();
            loop {
                match self.done_rx.recv() {
                    Ok((i, res)) => {
                        self.outstanding -= 1;
                        match res {
                            Ok(data) => {
                                if i == idx {
                                    pool.reacquire(self.entries[idx].region, &data);
                                    let _ = self.recycle_tx.send(data);
                                    self.stats.stall_ns += t0.elapsed().as_nanos() as u64;
                                    break;
                                }
                                self.staged.insert(i, data);
                            }
                            Err(err) => {
                                if i == idx {
                                    return Err(err);
                                }
                                // unrelated entry failed: record it there,
                                // keep waiting for ours
                                self.failed.insert(i, err);
                            }
                        }
                    }
                    Err(_) => {
                        return Err(Error::Runtime("swap prefetch thread died".into()))
                    }
                }
            }
        } else {
            // never issued (gap shorter than the issue horizon): inline
            let t0 = Instant::now();
            let region = self.entries[idx].region;
            let mut buf = vec![0f32; region.len];
            self.store.lock().unwrap().get(idx, &mut buf)?;
            pool.reacquire(region, &buf);
            self.stats.sync_fetches += 1;
            self.stats.stall_ns += t0.elapsed().as_nanos() as u64;
        }
        self.restored[idx] = true;
        self.residency.insert(self.entries[idx].tensor, Residency::Resident);
        self.stats.prefetches += 1;
        self.stats.bytes_in += (self.entries[idx].region.len * 4) as u64;
        self.pump_issues();
        Ok(())
    }

    fn drain_completions(&mut self) {
        while let Ok((i, res)) = self.done_rx.try_recv() {
            self.outstanding -= 1;
            match res {
                Ok(data) => {
                    self.staged.insert(i, data);
                }
                Err(err) => {
                    self.failed.insert(i, err);
                }
            }
        }
    }

    /// Issue background fetches in barrier-deadline (`due`) order, up to
    /// the current depth in flight. An entry not yet evicted blocks the
    /// queue — issuing later-deadline entries first would let a slow
    /// fetch starve an earlier barrier.
    fn pump_issues(&mut self) {
        while self.outstanding < self.depth && self.issue_cursor < self.by_prefetch.len() {
            let idx = self.by_prefetch[self.issue_cursor];
            if self.restored[idx] || self.issued[idx] {
                self.issue_cursor += 1;
                continue;
            }
            if !self.evicted[idx] {
                break;
            }
            if self.req_tx.send(Req::Fetch(idx)).is_err() {
                break; // worker gone; the sync fallback will surface it
            }
            self.issued[idx] = true;
            self.residency.insert(self.entries[idx].tensor, Residency::Fetching);
            self.outstanding += 1;
            self.issue_cursor += 1;
        }
    }

    /// Test hook: move one entry's prefetch deadline, desynchronizing the
    /// schedule from the plan — the residency guard (or the barrier
    /// inconsistency check) must then trip.
    #[doc(hidden)]
    pub fn delay_prefetch_for_test(&mut self, entry: usize, new_prefetch_before: u32) {
        let e = &mut self.entries[entry];
        e.prefetch_before = new_prefetch_before;
        e.due = new_prefetch_before.saturating_sub(e.lead);
        self.by_prefetch
            .sort_by_key(|&i| (self.entries[i].due, self.entries[i].prefetch_before, i));
    }

    /// Name of an entry's tensor (diagnostics, tests).
    pub fn entry_tensor_name(&self, entry: usize) -> &str {
        &self.entries[entry].name
    }

    /// An entry's `(evict_after, prefetch_before)` gap (diagnostics,
    /// tests).
    pub fn entry_gap(&self, entry: usize) -> (u32, u32) {
        (self.entries[entry].evict_after, self.entries[entry].prefetch_before)
    }
}

impl Drop for SwapExec {
    fn drop(&mut self) {
        let _ = self.req_tx.send(Req::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::offload::{OffloadEntry, PREFETCH_LEAD};
    use crate::runtime::store::HostStore;
    use crate::tensor::{CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable};

    fn table_one(eos: &[u32], len: usize) -> TensorTable {
        let mut t = TensorTable::new();
        let id = t
            .request("a", TensorDim::vec(1, len), TensorRole::Activation, CreateMode::Create, Initializer::None)
            .unwrap();
        for &e in eos {
            t.add_eo(id, e, Lifespan::FORWARD);
        }
        t.finish_orders();
        t.get_mut(id).region = Some(Region { offset: 0, len });
        t
    }

    fn plan_one(evict_after: u32, prefetch_before: u32, lead: u32, bytes: usize) -> OffloadPlan {
        OffloadPlan {
            entries: vec![OffloadEntry {
                tensor: 0,
                name: "a".into(),
                bytes,
                evict_after,
                prefetch_before,
                lead,
            }],
            primary_peak_bytes: bytes,
            swap_bytes_per_iter: 2 * bytes,
            fits: true,
            prefetch_depth: PREFETCH_DEPTH,
        }
    }

    /// Regression (schedule-head edge): a lead that swallows the gap
    /// would fire the completion barrier before the eviction — the
    /// entry would be judged resident while its fetch was never issued
    /// and the next iteration would silently train on garbage. The
    /// constructor must reject it for any lead, including the fixed
    /// default on a (corrupted) 1-EO gap.
    #[test]
    fn lead_swallowing_gap_is_rejected() {
        // gap of exactly 1 EO with the default lead 1
        let t = table_one(&[0, 1, 2], 16);
        let err = SwapExec::new(&t, &plan_one(0, 1, PREFETCH_LEAD, 64), Box::new(HostStore::new()), None)
            .err()
            .expect("gap-1 entry must be rejected");
        assert!(err.to_string().contains("swallowing"), "{err}");

        // calibrated-style wide lead on a wide gap
        let t = table_one(&[0, 10], 16);
        let err = SwapExec::new(&t, &plan_one(0, 10, 10, 64), Box::new(HostStore::new()), None)
            .err()
            .expect("gap-swallowing lead must be rejected");
        assert!(err.to_string().contains("swallowing"), "{err}");

        // the widest admissible lead still builds
        assert!(SwapExec::new(&t, &plan_one(0, 10, 9, 64), Box::new(HostStore::new()), None).is_ok());
    }

    /// The barrier order follows per-entry due EOs, not raw
    /// `prefetch_before`: a big entry with a wide lead must complete
    /// before a small entry whose deadline is nominally earlier.
    #[test]
    fn barrier_order_uses_due_not_prefetch_before() {
        let mut t = TensorTable::new();
        for (name, eos) in [("a", vec![0u32, 20]), ("b", vec![1u32, 12])] {
            let id = t
                .request(name, TensorDim::vec(1, 8), TensorRole::Activation, CreateMode::Create, Initializer::None)
                .unwrap();
            for e in eos {
                t.add_eo(id, e, Lifespan::FORWARD);
            }
        }
        t.finish_orders();
        t.get_mut(0).region = Some(Region { offset: 0, len: 8 });
        t.get_mut(1).region = Some(Region { offset: 8, len: 8 });
        let mut plan = plan_one(0, 20, 12, 32); // a: due at EO 8
        plan.entries.push(OffloadEntry {
            tensor: 1,
            name: "b".into(),
            bytes: 32,
            evict_after: 1,
            prefetch_before: 12, // due at EO 11 — later than a's despite earlier deadline
            lead: 1,
        });
        let sw = SwapExec::new(&t, &plan, Box::new(HostStore::new()), None).unwrap();
        assert_eq!(sw.entry_tensor_name(sw.by_prefetch[0]), "a");
        assert_eq!(sw.entry_tensor_name(sw.by_prefetch[1]), "b");
    }
}
