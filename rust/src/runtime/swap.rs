//! Proactive swap runtime: executes an [`OffloadPlan`] during training.
//!
//! The paper's stated future work — "we can swap in and out proactively
//! in background" — falls out of Algorithm 1's execution orders: every
//! tensor access point is known before training starts, so eviction and
//! prefetch are *scheduled*, not demand-paged. The protocol, per training
//! step at execution order `e`:
//!
//! 1. **pre-step** — complete every prefetch whose `prefetch_before` is
//!    within [`PREFETCH_LEAD`] of `e`: copy the staged bytes back into the
//!    tensor's pool region ([`MemoryPool::reacquire`]). If the background
//!    fetch has not finished, block (counted as swap stall); if it was
//!    never issued (gap shorter than the issue horizon), fetch inline.
//! 2. **residency guard** — no offloaded tensor may be `Evicted` or
//!    `Fetching` at one of its own use EOs. Any violation means the plan
//!    and the runtime have drifted; the step fails loudly instead of
//!    computing on poisoned data.
//! 3. **execute the layer phase** (the executor's job).
//! 4. **post-step** — evict every entry with `evict_after == e`: copy the
//!    region to the [`SecondaryStore`], release it
//!    ([`MemoryPool::release_gap`]), then top up the background prefetch
//!    queue (double-buffered: up to [`PREFETCH_DEPTH`] fetches in flight).
//!
//! The background thread only ever touches the store and its own staging
//! buffers — never the pool — so the pool stays single-threaded; the main
//! thread performs every region copy at a deterministic point in the step
//! order, which is what keeps swapped and unswapped training bitwise
//! identical (see `rust/tests/swap_equivalence.rs`).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::planner::offload::{OffloadPlan, PREFETCH_LEAD};
use crate::planner::pool::MemoryPool;
use crate::tensor::{Region, Residency, TensorId, TensorTable};

use super::store::SecondaryStore;

/// Number of background prefetches kept in flight (double buffering).
pub const PREFETCH_DEPTH: usize = 2;

/// One scheduled gap of one tensor (a tensor with several idle gaps per
/// iteration has one entry per gap).
struct SwapEntry {
    tensor: TensorId,
    name: String,
    region: Region,
    evict_after: u32,
    prefetch_before: u32,
}

/// Use points of an offloaded root tensor, for the residency guard.
struct RootInfo {
    name: String,
    eos: Vec<u32>,
}

enum Req {
    Fetch(usize),
    Stop,
}

/// Cumulative swap-runtime counters (whole run, not per iteration).
#[derive(Clone, Copy, Debug, Default)]
pub struct SwapStats {
    pub evictions: u64,
    pub prefetches: u64,
    /// Prefetches that had to run inline on the training thread because
    /// the gap was shorter than the issue horizon.
    pub sync_fetches: u64,
    pub bytes_out: u64,
    pub bytes_in: u64,
    /// Wall time the training thread spent waiting on swap-ins.
    pub stall_ns: u64,
}

impl SwapStats {
    pub fn stall_ms(&self) -> f64 {
        self.stall_ns as f64 / 1e6
    }
}

/// Executable swap schedule bound to one compiled model's pool layout.
pub struct SwapExec {
    entries: Vec<SwapEntry>,
    plan: OffloadPlan,
    /// EO → entries to evict right after the step at that EO.
    evict_at: HashMap<u32, Vec<usize>>,
    /// Entry indices sorted by `prefetch_before` — both the completion
    /// barrier order and the background issue order.
    by_prefetch: Vec<usize>,
    roots: HashMap<TensorId, RootInfo>,
    residency: HashMap<TensorId, Residency>,
    // per-iteration entry state
    evicted: Vec<bool>,
    issued: Vec<bool>,
    restored: Vec<bool>,
    staged: HashMap<usize, Vec<f32>>,
    failed: HashMap<usize, Error>,
    next_due: usize,
    issue_cursor: usize,
    outstanding: usize,
    store: Arc<Mutex<Box<dyn SecondaryStore>>>,
    store_kind: &'static str,
    req_tx: Sender<Req>,
    done_rx: Receiver<(usize, Result<Vec<f32>>)>,
    /// Staging buffers handed back to the worker for reuse, keeping the
    /// steady-state prefetch path allocation-free.
    recycle_tx: Sender<Vec<f32>>,
    worker: Option<JoinHandle<()>>,
    pub stats: SwapStats,
}

impl SwapExec {
    /// Build the schedule from a planned table (regions assigned by the
    /// gap-aware planner) and spawn the background prefetcher.
    pub fn new(
        table: &TensorTable,
        plan: &OffloadPlan,
        store: Box<dyn SecondaryStore>,
    ) -> Result<SwapExec> {
        let mut entries = Vec::with_capacity(plan.entries.len());
        let mut roots: HashMap<TensorId, RootInfo> = HashMap::new();
        let mut residency: HashMap<TensorId, Residency> = HashMap::new();
        for e in &plan.entries {
            let s = table.get(e.tensor);
            if e.evict_after >= e.prefetch_before {
                return Err(Error::planner(format!(
                    "offload entry for `{}` has an empty gap ({} >= {})",
                    s.name, e.evict_after, e.prefetch_before
                )));
            }
            let region = s.region.ok_or_else(|| {
                Error::planner(format!("offloaded tensor `{}` has no region", s.name))
            })?;
            entries.push(SwapEntry {
                tensor: e.tensor,
                name: s.name.clone(),
                region,
                evict_after: e.evict_after,
                prefetch_before: e.prefetch_before,
            });
            roots
                .entry(e.tensor)
                .or_insert_with(|| RootInfo { name: s.name.clone(), eos: s.eos.clone() });
            residency.insert(e.tensor, Residency::Resident);
        }
        let n = entries.len();
        let mut evict_at: HashMap<u32, Vec<usize>> = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            evict_at.entry(e.evict_after).or_default().push(i);
        }
        let mut by_prefetch: Vec<usize> = (0..n).collect();
        by_prefetch.sort_by_key(|&i| (entries[i].prefetch_before, i));

        let store_kind = store.kind();
        let store = Arc::new(Mutex::new(store));
        let (req_tx, req_rx) = channel::<Req>();
        let (done_tx, done_rx) = channel::<(usize, Result<Vec<f32>>)>();
        let (recycle_tx, recycle_rx) = channel::<Vec<f32>>();
        let lens: Vec<usize> = entries.iter().map(|e| e.region.len).collect();
        let wstore = Arc::clone(&store);
        let worker = std::thread::Builder::new()
            .name("nntrainer-prefetch".into())
            .spawn(move || {
                while let Ok(req) = req_rx.recv() {
                    match req {
                        Req::Fetch(i) => {
                            // reuse a returned staging buffer when one is
                            // available — steady state allocates nothing
                            let mut buf = recycle_rx.try_recv().unwrap_or_default();
                            if buf.len() != lens[i] {
                                buf.resize(lens[i], 0.0);
                            }
                            let res = wstore.lock().unwrap().get(i, &mut buf).map(|()| buf);
                            if done_tx.send((i, res)).is_err() {
                                break;
                            }
                        }
                        Req::Stop => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn prefetch thread: {e}")))?;

        Ok(SwapExec {
            entries,
            plan: plan.clone(),
            evict_at,
            by_prefetch,
            roots,
            residency,
            evicted: vec![false; n],
            issued: vec![false; n],
            restored: vec![false; n],
            staged: HashMap::new(),
            failed: HashMap::new(),
            next_due: 0,
            issue_cursor: 0,
            outstanding: 0,
            store,
            store_kind,
            req_tx,
            done_rx,
            recycle_tx,
            worker: Some(worker),
            stats: SwapStats::default(),
        })
    }

    pub fn plan(&self) -> &OffloadPlan {
        &self.plan
    }

    pub fn store_kind(&self) -> &'static str {
        self.store_kind
    }

    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    pub fn residency_of(&self, root: TensorId) -> Option<Residency> {
        self.residency.get(&root).copied()
    }

    /// Reset per-iteration state. Every entry must have been restored by
    /// the previous iteration's `end_iteration`.
    pub fn begin_iteration(&mut self) -> Result<()> {
        if self.outstanding != 0 || !self.staged.is_empty() {
            return Err(Error::Runtime(
                "swap runtime: stale prefetches at iteration start".into(),
            ));
        }
        self.evicted.iter_mut().for_each(|v| *v = false);
        self.issued.iter_mut().for_each(|v| *v = false);
        self.restored.iter_mut().for_each(|v| *v = false);
        self.residency.values_mut().for_each(|r| *r = Residency::Resident);
        self.failed.clear();
        self.next_due = 0;
        self.issue_cursor = 0;
        Ok(())
    }

    /// Complete every prefetch due at or before the step at `eo`.
    pub fn pre_step(&mut self, eo: u32, pool: &MemoryPool) -> Result<()> {
        while self.next_due < self.by_prefetch.len() {
            let idx = self.by_prefetch[self.next_due];
            if self.entries[idx].prefetch_before > eo.saturating_add(PREFETCH_LEAD) {
                break;
            }
            self.finish_prefetch(idx, pool)?;
            self.next_due += 1;
        }
        Ok(())
    }

    /// The residency guard: no offloaded tensor may be away from primary
    /// memory at one of its own use EOs. Catches plan/runtime drift (and
    /// deliberately corrupted plans) before a layer computes on poison.
    pub fn check_residency(&self, eo: u32) -> Result<()> {
        for (id, info) in &self.roots {
            let state = self.residency.get(id).copied().unwrap_or(Residency::Resident);
            if state != Residency::Resident && info.eos.binary_search(&eo).is_ok() {
                return Err(Error::Runtime(format!(
                    "residency violation: `{}` is {:?} at EO {eo}, one of its use points — \
                     the offload plan and the swap runtime have drifted",
                    info.name, state
                )));
            }
        }
        Ok(())
    }

    /// Evict entries whose gap starts after the step at `eo`, then top up
    /// the background prefetch queue.
    pub fn post_step(&mut self, eo: u32, pool: &MemoryPool) -> Result<()> {
        if let Some(idxs) = self.evict_at.get(&eo) {
            for &idx in idxs {
                let e = &self.entries[idx];
                self.store.lock().unwrap().put(idx, pool.view(e.region))?;
                pool.release_gap(e.region);
                self.evicted[idx] = true;
                self.residency.insert(e.tensor, Residency::Evicted);
                self.stats.evictions += 1;
                self.stats.bytes_out += (e.region.len * 4) as u64;
            }
        }
        self.drain_completions();
        self.pump_issues();
        Ok(())
    }

    /// Restore everything still out (e.g. a final gap whose prefetch EO
    /// has no step in this schedule) so weights/outputs can be read and
    /// the next iteration starts clean.
    pub fn end_iteration(&mut self, pool: &MemoryPool) -> Result<()> {
        for k in 0..self.by_prefetch.len() {
            let idx = self.by_prefetch[k];
            if !self.restored[idx] {
                self.finish_prefetch(idx, pool)?;
            }
        }
        self.next_due = self.by_prefetch.len();
        while self.outstanding > 0 {
            match self.done_rx.recv() {
                Ok((i, res)) => {
                    self.outstanding -= 1;
                    if let Ok(data) = res {
                        self.staged.insert(i, data);
                    }
                }
                Err(_) => return Err(Error::Runtime("swap prefetch thread died".into())),
            }
        }
        self.staged.clear();
        Ok(())
    }

    fn finish_prefetch(&mut self, idx: usize, pool: &MemoryPool) -> Result<()> {
        if self.restored[idx] {
            return Ok(());
        }
        if !self.evicted[idx] {
            // the gap never opened this iteration — data is still in the
            // pool region, nothing to copy
            self.restored[idx] = true;
            return Ok(());
        }
        if let Some(err) = self.failed.remove(&idx) {
            return Err(err);
        }
        if let Some(data) = self.staged.remove(&idx) {
            pool.reacquire(self.entries[idx].region, &data);
            let _ = self.recycle_tx.send(data);
        } else if self.issued[idx] {
            // in flight — wait for the worker (this is the swap stall)
            let t0 = Instant::now();
            loop {
                match self.done_rx.recv() {
                    Ok((i, res)) => {
                        self.outstanding -= 1;
                        match res {
                            Ok(data) => {
                                if i == idx {
                                    pool.reacquire(self.entries[idx].region, &data);
                                    let _ = self.recycle_tx.send(data);
                                    self.stats.stall_ns += t0.elapsed().as_nanos() as u64;
                                    break;
                                }
                                self.staged.insert(i, data);
                            }
                            Err(err) => {
                                if i == idx {
                                    return Err(err);
                                }
                                // unrelated entry failed: record it there,
                                // keep waiting for ours
                                self.failed.insert(i, err);
                            }
                        }
                    }
                    Err(_) => {
                        return Err(Error::Runtime("swap prefetch thread died".into()))
                    }
                }
            }
        } else {
            // never issued (gap shorter than the issue horizon): inline
            let t0 = Instant::now();
            let region = self.entries[idx].region;
            let mut buf = vec![0f32; region.len];
            self.store.lock().unwrap().get(idx, &mut buf)?;
            pool.reacquire(region, &buf);
            self.stats.sync_fetches += 1;
            self.stats.stall_ns += t0.elapsed().as_nanos() as u64;
        }
        self.restored[idx] = true;
        self.residency.insert(self.entries[idx].tensor, Residency::Resident);
        self.stats.prefetches += 1;
        self.stats.bytes_in += (self.entries[idx].region.len * 4) as u64;
        self.pump_issues();
        Ok(())
    }

    fn drain_completions(&mut self) {
        while let Ok((i, res)) = self.done_rx.try_recv() {
            self.outstanding -= 1;
            match res {
                Ok(data) => {
                    self.staged.insert(i, data);
                }
                Err(err) => {
                    self.failed.insert(i, err);
                }
            }
        }
    }

    /// Issue background fetches in deadline (`prefetch_before`) order, up
    /// to [`PREFETCH_DEPTH`] in flight. An entry not yet evicted blocks
    /// the queue — issuing later-deadline entries first would let a slow
    /// fetch starve an earlier barrier.
    fn pump_issues(&mut self) {
        while self.outstanding < PREFETCH_DEPTH && self.issue_cursor < self.by_prefetch.len() {
            let idx = self.by_prefetch[self.issue_cursor];
            if self.restored[idx] || self.issued[idx] {
                self.issue_cursor += 1;
                continue;
            }
            if !self.evicted[idx] {
                break;
            }
            if self.req_tx.send(Req::Fetch(idx)).is_err() {
                break; // worker gone; the sync fallback will surface it
            }
            self.issued[idx] = true;
            self.residency.insert(self.entries[idx].tensor, Residency::Fetching);
            self.outstanding += 1;
            self.issue_cursor += 1;
        }
    }

    /// Test hook: move one entry's prefetch deadline, desynchronizing the
    /// schedule from the plan — the residency guard must then trip.
    #[doc(hidden)]
    pub fn delay_prefetch_for_test(&mut self, entry: usize, new_prefetch_before: u32) {
        self.entries[entry].prefetch_before = new_prefetch_before;
        self.by_prefetch
            .sort_by_key(|&i| (self.entries[i].prefetch_before, i));
    }

    /// Name of an entry's tensor (diagnostics, tests).
    pub fn entry_tensor_name(&self, entry: usize) -> &str {
        &self.entries[entry].name
    }
}

impl Drop for SwapExec {
    fn drop(&mut self) {
        let _ = self.req_tx.send(Req::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}
