//! `DataProducer`: the user-extendable sample source (paper §4).

/// One training sample: input features + label, both flat f32.
#[derive(Clone, Debug, Default)]
pub struct Sample {
    pub input: Vec<f32>,
    pub label: Vec<f32>,
}

/// A source of samples. Implementations must be `Send` (the Batch Queue
/// runs them on a producer thread).
pub trait DataProducer: Send {
    /// Per-sample input length (must match the model input's feature
    /// size × 1 sample).
    fn input_len(&self) -> usize;
    /// Per-sample label length.
    fn label_len(&self) -> usize;
    /// Total samples per epoch.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Produce sample `idx` (0..len). Must be deterministic in `idx` for
    /// reproducibility (the paper's pull-request equivalence gate).
    fn sample(&mut self, idx: usize) -> Sample;
}

/// In-memory producer over pre-materialized samples (feature caching for
/// transfer learning — HandMoji's "cache the results from the feature
/// extractor in the first epoch").
pub struct CachedProducer {
    pub samples: Vec<Sample>,
}

impl CachedProducer {
    pub fn new(samples: Vec<Sample>) -> Self {
        CachedProducer { samples }
    }

    /// Materialize the first `n` samples of another producer — the
    /// personalization flows fine-tune on a small, fixed user dataset
    /// (the paper's "user reads 18 sentences").
    pub fn materialize(src: &mut dyn DataProducer, n: usize) -> Self {
        CachedProducer { samples: (0..n).map(|i| src.sample(i)).collect() }
    }
}

impl DataProducer for CachedProducer {
    fn input_len(&self) -> usize {
        self.samples.first().map(|s| s.input.len()).unwrap_or(0)
    }
    fn label_len(&self) -> usize {
        self.samples.first().map(|s| s.label.len()).unwrap_or(0)
    }
    fn len(&self) -> usize {
        self.samples.len()
    }
    fn sample(&mut self, idx: usize) -> Sample {
        self.samples[idx % self.samples.len()].clone()
    }
}
