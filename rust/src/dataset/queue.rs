//! Batch Queue: a bounded producer/consumer queue of batch buffers
//! (paper §4: "DataProducer generates data for training and accumulates
//! the data in the Batch Queue up to the batch size").
//!
//! The producer thread assembles `[batch, feat]` input / label buffers;
//! the bounded channel provides backpressure so at most `depth` batches
//! are in flight — on-device memory discipline applies to the data
//! pipeline too.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use super::producer::DataProducer;

/// A fully-assembled batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub input: Vec<f32>,
    pub label: Vec<f32>,
    /// Actual sample count (the tail batch may be short; it is dropped by
    /// default to keep shapes static, matching NNTrainer).
    pub n: usize,
}

/// Threaded batch assembler with bounded depth.
pub struct BatchQueue {
    rx: Receiver<Batch>,
    handle: Option<JoinHandle<()>>,
}

impl BatchQueue {
    /// Spawn the producer thread: one epoch of `producer`, batches of
    /// `batch` samples, at most `depth` pre-assembled batches in flight.
    pub fn spawn(mut producer: Box<dyn DataProducer>, batch: usize, depth: usize) -> BatchQueue {
        let (tx, rx) = sync_channel::<Batch>(depth.max(1));
        let handle = std::thread::spawn(move || {
            let n = producer.len();
            let in_len = producer.input_len();
            let lb_len = producer.label_len();
            let mut i = 0usize;
            while i + batch <= n {
                let mut b = Batch {
                    input: vec![0f32; in_len * batch],
                    label: vec![0f32; lb_len * batch],
                    n: batch,
                };
                for k in 0..batch {
                    let s = producer.sample(i + k);
                    debug_assert_eq!(s.input.len(), in_len);
                    debug_assert_eq!(s.label.len(), lb_len);
                    b.input[k * in_len..(k + 1) * in_len].copy_from_slice(&s.input);
                    b.label[k * lb_len..(k + 1) * lb_len].copy_from_slice(&s.label);
                }
                if tx.send(b).is_err() {
                    return; // consumer dropped — stop producing
                }
                i += batch;
            }
        });
        BatchQueue { rx, handle: Some(handle) }
    }

    /// Blocking pop; `None` when the epoch is exhausted.
    pub fn next(&self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

impl Drop for BatchQueue {
    fn drop(&mut self) {
        // Unblock the producer by dropping the receiver side first is not
        // possible here; joining is fine because the sender exits when
        // send() fails after rx is dropped with self.
        if let Some(h) = self.handle.take() {
            // Drain remaining items so the producer can finish.
            while self.rx.try_recv().is_ok() {}
            drop(std::mem::replace(&mut self.rx, {
                let (_tx, rx) = sync_channel::<Batch>(1);
                rx
            }));
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::producer::{CachedProducer, Sample};

    fn producer(n: usize) -> Box<dyn DataProducer> {
        Box::new(CachedProducer::new(
            (0..n)
                .map(|i| Sample { input: vec![i as f32; 4], label: vec![i as f32] })
                .collect(),
        ))
    }

    #[test]
    fn batches_complete_epoch() {
        let q = BatchQueue::spawn(producer(10), 3, 2);
        let mut seen = 0;
        while let Some(b) = q.next() {
            assert_eq!(b.n, 3);
            assert_eq!(b.input.len(), 12);
            seen += 1;
        }
        // 10 samples, batch 3 → 3 full batches, tail dropped
        assert_eq!(seen, 3);
    }

    #[test]
    fn batch_content_ordered() {
        let q = BatchQueue::spawn(producer(6), 2, 1);
        let b0 = q.next().unwrap();
        assert_eq!(b0.input[0], 0.0);
        assert_eq!(b0.input[4], 1.0);
        let b1 = q.next().unwrap();
        assert_eq!(b1.label, vec![2.0, 3.0]);
    }

    #[test]
    fn early_drop_does_not_hang() {
        let q = BatchQueue::spawn(producer(1000), 1, 2);
        let _ = q.next();
        drop(q); // must not deadlock
    }
}
