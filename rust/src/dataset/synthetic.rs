//! Synthetic workload producers — one per evaluation scenario (DESIGN.md
//! §Substitutions documents what each stands in for).

use crate::rng::Rng;

use super::producer::{DataProducer, Sample};

/// Uniform-random features + labels — the paper's component benchmarks
//  (Table 4 / Figs 9-11) train on synthetic data of the given shapes.
pub struct RandomProducer {
    pub n: usize,
    pub input_len: usize,
    pub label_len: usize,
    seed: u64,
}

impl RandomProducer {
    pub fn new(n: usize, input_len: usize, label_len: usize, seed: u64) -> Self {
        RandomProducer { n, input_len, label_len, seed }
    }
}

impl DataProducer for RandomProducer {
    fn input_len(&self) -> usize {
        self.input_len
    }
    fn label_len(&self) -> usize {
        self.label_len
    }
    fn len(&self) -> usize {
        self.n
    }
    fn sample(&mut self, idx: usize) -> Sample {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0x1234_5677));
        let mut s = Sample {
            input: vec![0f32; self.input_len],
            label: vec![0f32; self.label_len],
        };
        rng.fill_uniform(&mut s.input, -1.0, 1.0);
        // one-hot-ish label for classification shapes
        if self.label_len > 1 {
            s.label[rng.below(self.label_len)] = 1.0;
        } else {
            s.label[0] = rng.uniform(-1.0, 1.0);
        }
        Sample { input: s.input, label: s.label }
    }
}

/// Procedurally-drawn digit glyphs on a `side × side` canvas — a learnable
/// 10-class vision task for the end-to-end convergence runs (stands in
/// for MNIST; no datasets ship offline).
pub struct DigitsProducer {
    pub n: usize,
    pub side: usize,
    pub channels: usize,
    seed: u64,
}

impl DigitsProducer {
    pub fn new(n: usize, side: usize, channels: usize, seed: u64) -> Self {
        DigitsProducer { n, side, channels, seed }
    }

    /// 5x7 bitmap font for digits 0-9 (classic hex patterns).
    const FONT: [[u8; 7]; 10] = [
        [0x0E, 0x11, 0x13, 0x15, 0x19, 0x11, 0x0E], // 0
        [0x04, 0x0C, 0x04, 0x04, 0x04, 0x04, 0x0E], // 1
        [0x0E, 0x11, 0x01, 0x02, 0x04, 0x08, 0x1F], // 2
        [0x1F, 0x02, 0x04, 0x02, 0x01, 0x11, 0x0E], // 3
        [0x02, 0x06, 0x0A, 0x12, 0x1F, 0x02, 0x02], // 4
        [0x1F, 0x10, 0x1E, 0x01, 0x01, 0x11, 0x0E], // 5
        [0x06, 0x08, 0x10, 0x1E, 0x11, 0x11, 0x0E], // 6
        [0x1F, 0x01, 0x02, 0x04, 0x08, 0x08, 0x08], // 7
        [0x0E, 0x11, 0x11, 0x0E, 0x11, 0x11, 0x0E], // 8
        [0x0E, 0x11, 0x11, 0x0F, 0x01, 0x02, 0x0C], // 9
    ];
}

impl DataProducer for DigitsProducer {
    fn input_len(&self) -> usize {
        self.channels * self.side * self.side
    }
    fn label_len(&self) -> usize {
        10
    }
    fn len(&self) -> usize {
        self.n
    }
    fn sample(&mut self, idx: usize) -> Sample {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0x9E37_79B9));
        let digit = idx % 10;
        let side = self.side;
        let mut img = vec![0f32; self.input_len()];
        // random placement + intensity jitter
        let scale = (side / 8).max(1);
        let max_off = side.saturating_sub(5 * scale + 1);
        let ox = rng.below(max_off.max(1));
        let oy = rng.below(side.saturating_sub(7 * scale + 1).max(1));
        let amp = rng.uniform(0.7, 1.0);
        for (ry, row) in Self::FONT[digit].iter().enumerate() {
            for rx in 0..5 {
                if row & (1 << (4 - rx)) != 0 {
                    for sy in 0..scale {
                        for sx in 0..scale {
                            let y = oy + ry * scale + sy;
                            let x = ox + rx * scale + sx;
                            if y < side && x < side {
                                for c in 0..self.channels {
                                    img[c * side * side + y * side + x] = amp;
                                }
                            }
                        }
                    }
                }
            }
        }
        // light noise
        for v in img.iter_mut() {
            *v += rng.uniform(-0.05, 0.05);
        }
        let mut label = vec![0f32; 10];
        label[digit] = 1.0;
        Sample { input: img, label }
    }
}

/// MovieLens-shaped recommendation pairs: (user id, item id) → rating.
/// Preserves the tensor shapes that dominate Fig 12's Product-Rating
/// case (193610-row embedding table).
pub struct MovieLensProducer {
    pub n: usize,
    pub n_users: usize,
    pub n_items: usize,
    seed: u64,
}

impl MovieLensProducer {
    pub fn new(n: usize, n_users: usize, n_items: usize, seed: u64) -> Self {
        MovieLensProducer { n, n_users, n_items, seed }
    }
}

impl DataProducer for MovieLensProducer {
    fn input_len(&self) -> usize {
        2 // [user id, item id] as f32-encoded indices
    }
    fn label_len(&self) -> usize {
        1
    }
    fn len(&self) -> usize {
        self.n
    }
    fn sample(&mut self, idx: usize) -> Sample {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0xABCD_EF01));
        let u = rng.below(self.n_users);
        let m = rng.below(self.n_items);
        // latent-structure rating so the model has something to learn
        let r = 0.5 + 4.5 * (((u % 7) as f32 / 7.0) * ((m % 5) as f32 / 5.0));
        Sample {
            input: vec![u as f32, m as f32],
            label: vec![r / 5.0],
        }
    }
}

/// Sequence regression: noisy sinusoid windows → next value(s). Stands in
/// for the voice/mel-frame sequences of the TTS personalization case.
pub struct SeqProducer {
    pub n: usize,
    pub t: usize,
    pub feat: usize,
    pub label_len: usize,
    seed: u64,
}

impl SeqProducer {
    pub fn new(n: usize, t: usize, feat: usize, label_len: usize, seed: u64) -> Self {
        SeqProducer { n, t, feat, label_len, seed }
    }
}

impl DataProducer for SeqProducer {
    fn input_len(&self) -> usize {
        self.t * self.feat
    }
    fn label_len(&self) -> usize {
        self.label_len
    }
    fn len(&self) -> usize {
        self.n
    }
    fn sample(&mut self, idx: usize) -> Sample {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0x5555_AAAB));
        let phase = rng.uniform(0.0, std::f32::consts::TAU);
        let freq = rng.uniform(0.05, 0.3);
        let mut input = vec![0f32; self.input_len()];
        for step in 0..self.t {
            for f in 0..self.feat {
                input[step * self.feat + f] =
                    (phase + freq * (step as f32 + f as f32 * 0.1)).sin()
                        + rng.uniform(-0.02, 0.02);
            }
        }
        let mut label = vec![0f32; self.label_len];
        for (k, v) in label.iter_mut().enumerate() {
            *v = (phase + freq * (self.t as f32 + k as f32)).sin();
        }
        Sample { input, label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_are_deterministic_and_labeled() {
        let mut p = DigitsProducer::new(100, 16, 1, 7);
        let a = p.sample(13);
        let b = p.sample(13);
        assert_eq!(a.input, b.input);
        assert_eq!(a.label.iter().filter(|&&v| v == 1.0).count(), 1);
        assert_eq!(a.label[3], 1.0); // 13 % 10
    }

    #[test]
    fn digits_distinct_classes_differ() {
        let mut p = DigitsProducer::new(100, 16, 1, 7);
        let a = p.sample(0);
        let b = p.sample(1);
        assert_ne!(a.input, b.input);
    }

    #[test]
    fn movielens_ranges() {
        let mut p = MovieLensProducer::new(50, 100, 20, 3);
        for i in 0..50 {
            let s = p.sample(i);
            assert!(s.input[0] < 100.0);
            assert!(s.input[1] < 20.0);
            assert!((0.0..=1.0).contains(&s.label[0]));
        }
    }

    #[test]
    fn seq_shapes() {
        let mut p = SeqProducer::new(10, 20, 2, 1, 1);
        let s = p.sample(0);
        assert_eq!(s.input.len(), 40);
        assert_eq!(s.label.len(), 1);
        assert!(s.input.iter().all(|v| v.abs() <= 1.1));
    }
}
