//! Data pipeline (paper §4 *setData*): `DataProducer` generates samples,
//! the threaded `BatchQueue` accumulates them into batch-sized buffers
//! with backpressure, and synthetic producers provide every workload the
//! evaluation needs (see DESIGN.md §Substitutions for why synthetic).

pub mod producer;
pub mod queue;
pub mod synthetic;

pub use producer::{DataProducer, Sample};
pub use queue::BatchQueue;
pub use synthetic::{DigitsProducer, MovieLensProducer, RandomProducer, SeqProducer};
