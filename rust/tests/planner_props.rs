//! Property tests over the memory planners (offline substitute for
//! proptest — seeded xorshift generators, many random cases).
//!
//! Invariants:
//!  1. No two live tensors overlap (validate_plan) — for every planner.
//!  2. pool ≥ analytic ideal; naive ≥ sorting; bestfit ≤ sorting.
//!  3. Planning is deterministic.
//!  4. Randomly-generated *graphs* (not just intervals) plan validly.

use nntrainer::compiler::realizer::realize_all;
use nntrainer::exec::{ideal_peak_bytes, init_graph, InitOptions};
use nntrainer::graph::{Graph, NodeDesc};
use nntrainer::layers::{builtin_factories, Props};
use nntrainer::planner::validate::{validate_merges, validate_plan};
use nntrainer::planner::{BestFitPlanner, NaivePlanner, Planner, SortingPlanner};
use nntrainer::rng::Rng;
use nntrainer::tensor::{CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable};

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

/// Random interval workload straight into a TensorTable.
fn random_table(rng: &mut Rng, n_tensors: usize, eo_max: u32) -> TensorTable {
    let mut t = TensorTable::new();
    for i in 0..n_tensors {
        let id = t
            .request(
                format!("t{i}"),
                TensorDim::vec(1, 1 + rng.below(4096)),
                TensorRole::Temp,
                CreateMode::Create,
                Initializer::None,
            )
            .unwrap();
        let a = rng.below(eo_max as usize) as u32;
        let b = rng.below(eo_max as usize) as u32;
        t.add_eo(id, a.min(b), Lifespan::FORWARD);
        t.add_eo(id, a.max(b), Lifespan::CALC_DERIV);
    }
    t.finish_orders();
    t
}

#[test]
fn prop_planners_valid_on_random_intervals() {
    let mut rng = Rng::new(2024);
    let (mut wins, mut total) = (0usize, 0usize);
    for case in 0..60 {
        let n = 5 + rng.below(60);
        let eo_max = 3 + rng.below(40) as u32;
        let base = random_table(&mut rng, n, eo_max);
        let ideal = ideal_peak_bytes(&base);

        let mut results = vec![];
        for planner in [&NaivePlanner as &dyn Planner, &SortingPlanner, &BestFitPlanner] {
            let mut t = base.clone();
            let len = planner.plan(&mut t).unwrap();
            validate_plan(&t, len).unwrap_or_else(|e| panic!("case {case} {}: {e}", planner.name()));
            assert!(len * 4 >= ideal, "case {case} {}: {} < ideal {}", planner.name(), len * 4, ideal);
            results.push(len);
        }
        let (naive, sorting, bestfit) = (results[0], results[1], results[2]);
        assert!(sorting <= naive, "case {case}: sorting {sorting} > naive {naive}");
        // best-fit splitting is not *universally* better (classic
        // allocator result) — allow small regressions, track wins below.
        assert!(
            bestfit as f64 <= sorting as f64 * 1.25,
            "case {case}: bestfit {bestfit} pathologically above sorting {sorting}"
        );
        if bestfit <= sorting {
            wins += 1;
        }
        total += 1;
    }
    assert!(
        wins * 10 >= total * 8,
        "bestfit should win >=80% of cases: {wins}/{total}"
    );
}

#[test]
fn prop_planning_is_deterministic() {
    let mut rng = Rng::new(7);
    let base = random_table(&mut rng, 40, 24);
    let mut t1 = base.clone();
    let mut t2 = base.clone();
    SortingPlanner.plan(&mut t1).unwrap();
    SortingPlanner.plan(&mut t2).unwrap();
    for (a, b) in t1.iter().zip(t2.iter()) {
        assert_eq!(a.region, b.region, "{}", a.name);
    }
}

/// Random *model graphs*: chains of random layers with occasional fan-out,
/// realized, initialized, planned and validated end to end.
#[test]
fn prop_random_graphs_plan_validly() {
    let mut rng = Rng::new(99);
    for case in 0..25 {
        let depth = 2 + rng.below(6);
        let mut nodes = vec![node("in", "input", &[("input_shape", "1:1:24")])];
        let mut units = 24usize;
        for d in 0..depth {
            let name = format!("l{d}");
            let choice = rng.below(4);
            let nd = match choice {
                0 => {
                    units = 4 + rng.below(24);
                    NodeDesc::new(
                        &name,
                        "fully_connected",
                        Props::from_pairs([("unit", units.to_string().as_str())]),
                    )
                }
                1 => NodeDesc::new(
                    &name,
                    "activation",
                    Props::from_pairs([(
                        "act",
                        ["sigmoid", "relu", "tanh"][rng.below(3)],
                    )]),
                ),
                2 => NodeDesc::new(&name, "flatten", Props::new()),
                _ => NodeDesc::new(
                    &name,
                    "dropout",
                    Props::from_pairs([("rate", "0.3")]),
                ),
            };
            nodes.push(nd);
        }
        nodes.push(node("loss", "mse", &[]));
        let realized = realize_all(nodes).unwrap();
        let graph = Graph::wire(realized).unwrap();
        let batch = 1 + rng.below(8);
        let ig = init_graph(
            &graph,
            &builtin_factories(),
            &InitOptions { batch, ..Default::default() },
        )
        .unwrap_or_else(|e| panic!("case {case}: init {e}"));
        for planner in [&SortingPlanner as &dyn Planner, &BestFitPlanner] {
            let mut t = ig.table.clone();
            let len = planner.plan(&mut t).unwrap();
            validate_plan(&t, len).unwrap_or_else(|e| panic!("case {case} {}: {e}", planner.name()));
            validate_merges(&t).unwrap();
        }
    }
}

/// Weights / optimizer state must never share space with anything:
/// their [0, apply] interval pins them.
#[test]
fn prop_weights_never_aliased() {
    let nodes = vec![
        node("in", "input", &[("input_shape", "1:1:32")]),
        node("fc0", "fully_connected", &[("unit", "32"), ("activation", "sigmoid")]),
        node("fc1", "fully_connected", &[("unit", "8")]),
        node("loss", "mse", &[]),
    ];
    let realized = realize_all(nodes).unwrap();
    let graph = Graph::wire(realized).unwrap();
    let ig = init_graph(
        &graph,
        &builtin_factories(),
        &InitOptions { batch: 4, opt_slots: 2, ..Default::default() },
    )
    .unwrap();
    let mut t = ig.table.clone();
    let _len = SortingPlanner.plan(&mut t).unwrap();
    let weights: Vec<_> = t
        .iter()
        .filter(|s| matches!(s.role, TensorRole::Weight | TensorRole::OptState))
        .filter(|s| s.merged_into.is_none())
        .map(|s| s.region.unwrap())
        .collect();
    let others: Vec<_> = t
        .iter()
        .filter(|s| !matches!(s.role, TensorRole::Weight | TensorRole::OptState))
        .filter(|s| s.merged_into.is_none() && !s.eos.is_empty())
        .map(|s| s.region.unwrap())
        .collect();
    for w in &weights {
        for o in &others {
            assert!(!w.overlaps(o), "weight region {w:?} aliased by {o:?}");
        }
    }
}

/// Failure injection: the validator actually catches corrupted plans.
#[test]
fn validator_catches_overlap() {
    let mut rng = Rng::new(3);
    let mut t = random_table(&mut rng, 20, 12);
    let len = SortingPlanner.plan(&mut t).unwrap();
    validate_plan(&t, len).unwrap();
    // corrupt: force tensor 1 onto tensor 0's offset with overlapping EOs
    let r0 = t.get(0).region.unwrap();
    t.get_mut(1).region = Some(r0);
    let e0: Vec<u32> = t.get(0).eos.clone();
    t.get_mut(1).eos = e0;
    assert!(validate_plan(&t, len).is_err());
}

#[test]
fn validator_catches_out_of_pool() {
    let mut rng = Rng::new(4);
    let mut t = random_table(&mut rng, 5, 6);
    let len = SortingPlanner.plan(&mut t).unwrap();
    let r = t.get(0).region.unwrap();
    t.get_mut(0).region = Some(nntrainer::tensor::Region { offset: len, len: r.len });
    assert!(validate_plan(&t, len).is_err());
}
