//! Placer property suite: the three gap-aware placement tiers over the
//! randomized stress topologies (the same four families as
//! `tests/swap_stress.rs`), holding three contracts per sample:
//!
//! * **validity** — every placer's realized layout re-validates against
//!   the offload plan over the allocated pool (`validate_gap_plan`);
//! * **peak ordering** — the placement portfolio is nested (the skyline
//!   tier evaluates a superset of the best-fit tier's candidates, which
//!   supersets first-fit's), so peaks must be monotone:
//!   `skyline <= best-fit <= first-fit` on *every* topology;
//! * **bitwise equivalence** — training under a budget through any
//!   placer x store combination, with an epoch-boundary pool compaction
//!   in the middle, is bitwise identical to unswapped training (losses
//!   every iteration, all weights at the end).
//!
//! Odd samples compile with cross-iteration swap pipelining
//! (`swap_pipeline`): the plans then carry wrap entries, so all three
//! contracts also cover the boundary geometry — wrap placement validity,
//! peak nesting over wrap intervals, and bitwise equivalence while
//! transfers carry across `end_iteration` (compaction quiesces them
//! first; the run end drains via `quiesce_swap` before weights are
//! read).
//!
//! Knobs: `NNTRAINER_STRESS_SEEDS` (comma-separated u64 seeds, default
//! `20260731`) and `NNTRAINER_STRESS_SAMPLES` (topologies per seed,
//! default 6) — the same contract as the swap-stress suite, so the CI
//! matrix drives both.

use nntrainer::compiler::CompileOpts;
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{Model, ModelBuilder};
use nntrainer::planner::offload::advise;
use nntrainer::planner::validate::validate_gap_plan;
use nntrainer::planner::PlannerKind;
use nntrainer::rng::Rng;
use nntrainer::runtime::StoreKind;

fn node(name: &str, ltype: &str, pairs: &[(&str, String)]) -> NodeDesc {
    NodeDesc::new(
        name,
        ltype,
        Props::from_pairs(pairs.iter().map(|(k, v)| (*k, v.as_str()))),
    )
}

/// One random topology out of the four families the paper's evaluation
/// models span (kept in lockstep with `tests/swap_stress.rs::gen_model`
/// so both suites cover the same shape space).
fn gen_model(rng: &mut Rng) -> Vec<NodeDesc> {
    match rng.below(4) {
        0 => {
            let feat = 32 + rng.below(128);
            let depth = 2 + rng.below(3);
            let mut nodes = vec![node(
                "in",
                "input",
                &[("input_shape", format!("1:1:{feat}"))],
            )];
            for i in 0..depth {
                let unit = 16 + rng.below(80);
                nodes.push(node(
                    &format!("h{i}"),
                    "fully_connected",
                    &[("unit", unit.to_string()), ("activation", "relu".into())],
                ));
            }
            nodes.push(node("out", "fully_connected", &[("unit", "8".into())]));
            nodes.push(node("loss", "mse", &[]));
            nodes
        }
        1 => {
            let c = 1 + rng.below(4);
            let hw = [8, 12, 16][rng.below(3)];
            let depth = 1 + rng.below(3);
            let mut nodes = vec![node(
                "in",
                "input",
                &[("input_shape", format!("{c}:{hw}:{hw}"))],
            )];
            for i in 0..depth {
                let filters = 4 + rng.below(12);
                nodes.push(node(
                    &format!("c{i}"),
                    "conv2d",
                    &[
                        ("filters", filters.to_string()),
                        ("kernel_size", "3".into()),
                        ("padding", "same".into()),
                        ("activation", "relu".into()),
                    ],
                ));
            }
            nodes.push(node("flat", "flatten", &[]));
            nodes.push(node("fc", "fully_connected", &[("unit", "10".into())]));
            nodes.push(node("loss", "mse", &[]));
            nodes
        }
        2 => {
            let feat = 32 + rng.below(96);
            let ua = 16 + rng.below(48);
            let ub = 16 + rng.below(48);
            vec![
                node("in", "input", &[("input_shape", format!("1:1:{feat}"))]),
                node("stem", "fully_connected", &[("unit", "48".into()), ("activation", "relu".into())]),
                node("mo", "multiout", &[("outputs", "2".into())]),
                node("ba", "fully_connected", &[("unit", ua.to_string()), ("activation", "relu".into()), ("input_layers", "mo(0)".into())]),
                node("bb", "fully_connected", &[("unit", ub.to_string()), ("activation", "relu".into()), ("input_layers", "mo(1)".into())]),
                node("cat", "concat", &[("input_layers", "ba,bb".into())]),
                node("head", "fully_connected", &[("unit", "8".into())]),
                node("loss", "mse", &[]),
            ]
        }
        _ => {
            let feat = 64 + rng.below(128);
            let unit = 24 + rng.below(64);
            vec![
                node("in", "input", &[("input_shape", format!("1:1:{feat}"))]),
                node("stem", "fully_connected", &[("unit", unit.to_string()), ("bias", "false".into())]),
                node("mo", "multiout", &[("outputs", "2".into())]),
                node("act_a", "activation", &[("act", "sigmoid".into()), ("input_layers", "mo(0)".into())]),
                node("act_b", "activation", &[("act", "relu".into()), ("input_layers", "mo(1)".into())]),
                node("add", "addition", &[("input_layers", "act_a,act_b".into())]),
                node("head", "fully_connected", &[("unit", "10".into()), ("bias", "false".into())]),
                node("loss", "mse", &[]),
            ]
        }
    }
}

fn compile(nodes: Vec<NodeDesc>, opts: &CompileOpts) -> Model {
    ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .compile(opts)
        .unwrap()
}

fn feat_lens(m: &Model) -> (usize, usize) {
    let in_len = m
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len = m
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    (in_len, lb_len)
}

fn env_seeds() -> Vec<u64> {
    match std::env::var("NNTRAINER_STRESS_SEEDS") {
        Ok(s) => {
            let seeds: Vec<u64> = s
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|e| {
                        panic!("NNTRAINER_STRESS_SEEDS part {p:?} is not a u64: {e}")
                    })
                })
                .collect();
            if seeds.is_empty() {
                panic!("NNTRAINER_STRESS_SEEDS={s:?} names no seeds");
            }
            seeds
        }
        Err(std::env::VarError::NotPresent) => vec![20260731],
        Err(e) => panic!("NNTRAINER_STRESS_SEEDS is set but unreadable: {e}"),
    }
}

fn env_samples() -> usize {
    match std::env::var("NNTRAINER_STRESS_SAMPLES") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            Ok(_) => panic!("NNTRAINER_STRESS_SAMPLES must be > 0"),
            Err(e) => panic!("NNTRAINER_STRESS_SAMPLES={v:?} is not a usize: {e}"),
        },
        Err(std::env::VarError::NotPresent) => 6,
        Err(e) => panic!("NNTRAINER_STRESS_SAMPLES is set but unreadable: {e}"),
    }
}

/// (topology, batch, budget) for a stress sample, derived exactly as the
/// swap-stress suite derives them so failures cross-reference.
fn sample_setup(seed: u64, sample: usize) -> (Vec<NodeDesc>, usize, usize) {
    let mut rng = Rng::new(seed ^ (sample as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let nodes = gen_model(&mut rng);
    let batch = [4usize, 8][rng.below(2)];
    let budget_pct = 60 + rng.below(31); // 60..=90 %
    let base = compile(nodes.clone(), &CompileOpts { batch, ..Default::default() });
    let full = advise(&base.exec.graph.table, usize::MAX).primary_peak_bytes;
    let budget = (full * budget_pct / 100).max(1);
    (nodes, batch, budget)
}

/// Compile under `budget` with one placer; validate the realized layout
/// and return the achieved pool bytes.
fn placed_peak(
    ctx: &str,
    nodes: Vec<NodeDesc>,
    batch: usize,
    budget: usize,
    placer: PlannerKind,
    pipeline: bool,
) -> usize {
    let m = compile(
        nodes,
        &CompileOpts {
            batch,
            memory_budget_bytes: Some(budget),
            planner: placer,
            swap_pipeline: pipeline,
            ..Default::default()
        },
    );
    let plan = m.exec.swap_plan().unwrap().clone();
    let pool_len = m.exec.pool.len();
    validate_gap_plan(&m.exec.graph.table, &plan, pool_len)
        .unwrap_or_else(|e| panic!("{ctx}: {placer:?} realized plan invalid: {e}"));
    m.peak_pool_bytes()
}

/// Portfolio nesting made observable: for every stress topology the
/// skyline tier's peak is at most best-fit's, which is at most
/// first-fit's.
#[test]
fn placer_peaks_are_ordered_on_stress_topologies() {
    let samples = env_samples();
    for &seed in &env_seeds() {
        for sample in 0..samples {
            // odd samples plan boundary (wrap) entries too: the nesting
            // must hold over their wrap-interval reservations as well
            let pipeline = sample % 2 == 1;
            let ctx = format!("seed={seed} sample={sample} pipeline={pipeline}");
            let (nodes, batch, budget) = sample_setup(seed, sample);
            let ff =
                placed_peak(&ctx, nodes.clone(), batch, budget, PlannerKind::Sorting, pipeline);
            let bf =
                placed_peak(&ctx, nodes.clone(), batch, budget, PlannerKind::BestFit, pipeline);
            let sky = placed_peak(&ctx, nodes, batch, budget, PlannerKind::Skyline, pipeline);
            assert!(
                sky <= bf,
                "{ctx}: skyline peak {sky} exceeds best-fit {bf} — the portfolio \
                 lost its nesting"
            );
            assert!(
                bf <= ff,
                "{ctx}: best-fit peak {bf} exceeds first-fit {ff} — the portfolio \
                 lost its nesting"
            );
        }
    }
}

/// Bitwise training equivalence through every placer x store combo with
/// a pool compaction applied mid-run: 2 iterations, the epoch-boundary
/// compaction (region relocation + arena truncation + swap rebind), then
/// 2 more iterations — losses and final weights must match unswapped
/// training exactly.
fn run_equivalence_sample(
    seed: u64,
    sample: usize,
    placer: PlannerKind,
    store: StoreKind,
    pipeline: bool,
) {
    let ctx = format!(
        "seed={seed} sample={sample} placer={placer:?} store={store:?} pipeline={pipeline}"
    );
    let (nodes, batch, budget) = sample_setup(seed, sample);

    let mut base = compile(nodes.clone(), &CompileOpts { batch, ..Default::default() });
    let mut swapped = compile(
        nodes,
        &CompileOpts {
            batch,
            memory_budget_bytes: Some(budget),
            planner: placer,
            swap_store: store,
            pool_compaction: true,
            swap_pipeline: pipeline,
            ..Default::default()
        },
    );
    assert!(swapped.exec.swap_active(), "{ctx}: swap runtime not engaged");

    let (in_len, lb_len) = feat_lens(&base);
    let mut data_rng = Rng::new(0xC0FFEE ^ seed);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    let mut compacted = false;
    for it in 0..4 {
        data_rng.fill_uniform(&mut input, -1.0, 1.0);
        data_rng.fill_uniform(&mut label, 0.0, 1.0);
        base.bind_batch(&input, &label).unwrap();
        swapped.bind_batch(&input, &label).unwrap();
        let l0 = base.exec.try_train_iteration().unwrap();
        let l1 = swapped
            .exec
            .try_train_iteration()
            .unwrap_or_else(|e| panic!("{ctx}: swapped iteration {it} failed: {e}"));
        assert_eq!(
            l0.to_bits(),
            l1.to_bits(),
            "{ctx}: iteration {it} loss diverged ({l0} vs {l1}, compacted={compacted})"
        );
        if it == 1 {
            // the epoch boundary: end_iteration has drained every
            // transfer, so the parked compaction may apply here
            let before = swapped.exec.pool.len();
            let applied = swapped
                .exec
                .compact_pool()
                .unwrap_or_else(|e| panic!("{ctx}: compaction failed: {e}"));
            compacted = applied;
            if applied {
                assert!(
                    swapped.exec.pool.len() <= before,
                    "{ctx}: compaction grew the pool ({before} -> {})",
                    swapped.exec.pool.len()
                );
                // the relocated layout must still validate
                let plan = swapped.exec.swap_plan().unwrap().clone();
                validate_gap_plan(&swapped.exec.graph.table, &plan, swapped.exec.pool.len())
                    .unwrap_or_else(|e| panic!("{ctx}: compacted plan invalid: {e}"));
            }
            assert!(
                !swapped.exec.swap_mut().unwrap().has_compaction(),
                "{ctx}: compaction must be one-shot"
            );
        }
    }

    // run end is a mandatory full-drain point: under pipelining the
    // engine may still carry boundary transfers over weight regions
    if pipeline {
        swapped
            .exec
            .quiesce_swap()
            .unwrap_or_else(|e| panic!("{ctx}: quiesce failed: {e}"));
    }

    for w in base.exec.weight_names() {
        let a = base.exec.read_weight(&w).unwrap();
        let b = swapped.exec.read_weight(&w).unwrap();
        assert_eq!(a.len(), b.len(), "{ctx}: {w}: length");
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: {w}[{k}]: {x} vs {y} (compacted={compacted})"
            );
        }
    }
}

#[test]
fn training_is_bitwise_across_placers_stores_and_compaction() {
    let placers = [PlannerKind::Sorting, PlannerKind::BestFit, PlannerKind::Skyline];
    let stores = [StoreKind::Host, StoreKind::File, StoreKind::FileCompressed];
    let samples = env_samples();
    for &seed in &env_seeds() {
        for sample in 0..samples {
            // walk the 3x3 placer x store grid across samples so every
            // combination appears at least once per 9 samples while each
            // individual sample stays cheap
            let placer = placers[sample % placers.len()];
            let store = stores[(sample / placers.len() + sample) % stores.len()];
            // odd samples additionally run the cross-iteration pipeline
            run_equivalence_sample(seed, sample, placer, store, sample % 2 == 1);
        }
    }
}
