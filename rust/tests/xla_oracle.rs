//! XLA-vs-native equivalence — the paper's own correctness methodology
//! ("we confirm the correctness by comparing every activation and weight
//! value … errors at 1e-4 level"), with the JAX/Pallas AOT artifacts as
//! the oracle and the Rust native engine as the system under test.
//!
//! Requires `make artifacts` (skips with a message otherwise, so plain
//! `cargo test` stays green in a fresh checkout).

use nntrainer::compiler::CompileOpts;
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{zoo, ModelBuilder};
use nntrainer::rng::Rng;
use nntrainer::runtime::catalog::{self, ArtifactCatalog};
use nntrainer::runtime::XlaRuntime;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

fn runtime_or_skip() -> Option<XlaRuntime> {
    let dir = ArtifactCatalog::default_dir();
    match ArtifactCatalog::open(&dir) {
        Ok(_) => Some(XlaRuntime::new(dir).expect("PJRT client")),
        Err(e) => {
            eprintln!("SKIP xla_oracle: {e}");
            None
        }
    }
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let denom = w.abs().max(1.0);
        assert!(
            (g - w).abs() / denom < tol,
            "{what}[{i}]: native {g} vs xla {w}"
        );
    }
}

#[test]
fn linear_forward_matches_xla() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (m, k, n) = catalog::ORACLE_LINEAR;
    let mut rng = Rng::new(11);
    let mut x = vec![0f32; m * k];
    let mut w = vec![0f32; k * n];
    let mut b = vec![0f32; n];
    rng.fill_uniform(&mut x, -1.0, 1.0);
    rng.fill_uniform(&mut w, -0.5, 0.5);
    rng.fill_uniform(&mut b, -0.1, 0.1);

    for (artifact, act) in [("oracle_linear_fwd", None), ("oracle_linear_sigmoid_fwd", Some("sigmoid"))] {
        let out = rt
            .run_f32(artifact, &[(&x[..], &[m, k][..]), (&w[..], &[k, n][..]), (&b[..], &[n][..])])
            .unwrap();
        let want = &out[0];

        let kstr = k.to_string();
        let nstr = n.to_string();
        let mut pairs: Vec<(&str, &str)> = vec![("unit", nstr.as_str())];
        if let Some(a) = act {
            pairs.push(("activation", a));
        }
        let shape = format!("1:1:{kstr}");
        let mut model = ModelBuilder::new()
            .add_nodes(vec![
                node("in", "input", &[("input_shape", shape.as_str())]),
                node("fc", "fully_connected", &pairs),
            ])
            .optimizer("sgd", &[])
            .compile(&CompileOpts { batch: m, training: false, ..Default::default() })
            .unwrap();
        model.exec.write_weight("fc:weight", &w).unwrap();
        model.exec.write_weight("fc:bias", &b).unwrap();
        model.exec.bind_input(0, &x).unwrap();
        model.exec.forward_pass();
        let got = model
            .exec
            .read_output(if act.is_some() { "fc/activation" } else { "fc" })
            .unwrap();
        assert_close(&got, want, 1e-4, artifact);
    }
}

#[test]
fn conv2d_forward_matches_xla() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (b, c, h, w_, oc, kk) = catalog::ORACLE_CONV;
    let mut rng = Rng::new(22);
    let mut x = vec![0f32; b * c * h * w_];
    let mut w = vec![0f32; oc * c * kk * kk];
    rng.fill_uniform(&mut x, -1.0, 1.0);
    rng.fill_uniform(&mut w, -0.3, 0.3);
    let out = rt
        .run_f32("oracle_conv2d_fwd", &[(&x[..], &[b, c, h, w_][..]), (&w[..], &[oc, c, kk, kk][..])])
        .unwrap();
    let want = &out[0];

    let shape = format!("{c}:{h}:{w_}");
    let f = oc.to_string();
    let kstr = kk.to_string();
    let mut model = ModelBuilder::new()
        .add_nodes(vec![
            node("in", "input", &[("input_shape", shape.as_str())]),
            node(
                "conv",
                "conv2d",
                &[("filters", f.as_str()), ("kernel_size", kstr.as_str()), ("padding", "same"), ("bias", "false")],
            ),
        ])
        .optimizer("sgd", &[])
        .compile(&CompileOpts { batch: b, training: false, ..Default::default() })
        .unwrap();
    model.exec.write_weight("conv:kernel", &w).unwrap();
    model.exec.bind_input(0, &x).unwrap();
    model.exec.forward_pass();
    let got = model.exec.read_output("conv").unwrap();
    assert_close(&got, want, 1e-4, "conv2d");
}

#[test]
fn lstm_forward_matches_xla() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (b, t, i, h) = catalog::ORACLE_LSTM;
    let mut rng = Rng::new(33);
    let mut x = vec![0f32; b * t * i];
    let mut wx = vec![0f32; i * 4 * h];
    let mut wh = vec![0f32; h * 4 * h];
    let mut bias = vec![0f32; 4 * h];
    rng.fill_uniform(&mut x, -1.0, 1.0);
    rng.fill_uniform(&mut wx, -0.4, 0.4);
    rng.fill_uniform(&mut wh, -0.4, 0.4);
    rng.fill_uniform(&mut bias, -0.1, 0.1);
    let out = rt
        .run_f32(
            "oracle_lstm_fwd",
            &[(&x[..], &[b, t, i][..]), (&wx[..], &[i, 4 * h][..]), (&wh[..], &[h, 4 * h][..]), (&bias[..], &[4 * h][..])],
        )
        .unwrap();
    let want = &out[0];

    let shape = format!("1:{t}:{i}");
    let unit = h.to_string();
    let mut model = ModelBuilder::new()
        .add_nodes(vec![
            node("in", "input", &[("input_shape", shape.as_str())]),
            node("lstm", "lstm", &[("unit", unit.as_str()), ("return_sequences", "true")]),
        ])
        .optimizer("sgd", &[])
        .compile(&CompileOpts { batch: b, training: false, ..Default::default() })
        .unwrap();
    model.exec.write_weight("lstm:weight_xh", &wx).unwrap();
    model.exec.write_weight("lstm:weight_hh", &wh).unwrap();
    model.exec.write_weight("lstm:bias", &bias).unwrap();
    model.exec.bind_input(0, &x).unwrap();
    model.exec.forward_pass();
    let got = model.exec.read_output("lstm").unwrap();
    assert_close(&got, want, 2e-4, "lstm");
}

#[test]
fn softmax_xent_matches_xla() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (r, c) = catalog::ORACLE_XENT;
    let mut rng = Rng::new(44);
    let mut z = vec![0f32; r * c];
    rng.fill_uniform(&mut z, -3.0, 3.0);
    // one-hot labels
    let mut y = vec![0f32; r * c];
    for row in 0..r {
        y[row * c + row % c] = 1.0;
    }
    let out = rt
        .run_f32("oracle_softmax_xent", &[(&z[..], &[r, c][..]), (&y[..], &[r, c][..])])
        .unwrap();
    let loss_rows = &out[0];
    let want_mean: f32 = loss_rows.iter().sum::<f32>() / r as f32;

    let feat = c.to_string();
    let shape = format!("1:1:{c}");
    let mut model = ModelBuilder::new()
        .add_nodes(vec![
            node("in", "input", &[("input_shape", shape.as_str())]),
            node("fc", "fully_connected", &[("unit", feat.as_str()), ("bias", "false")]),
            node("loss", "cross_entropy", &[]),
        ])
        .optimizer("sgd", &[("learning_rate", "0.0")])
        .compile(&CompileOpts { batch: r, ..Default::default() })
        .unwrap();
    // identity weight so fc output == the bound input == logits
    let mut eye = vec![0f32; c * c];
    for d in 0..c {
        eye[d * c + d] = 1.0;
    }
    model.exec.write_weight("fc:weight", &eye).unwrap();
    model.bind_batch(&z, &y).unwrap();
    let native_loss = model.exec.train_iteration();
    assert!(
        (native_loss - want_mean).abs() / want_mean.abs().max(1.0) < 1e-4,
        "native {native_loss} vs xla {want_mean}"
    );
}

/// The headline test: one full SGD train step of the demo MLP — native
/// engine vs the AOT JAX/Pallas artifact — weights and loss must agree
/// to 1e-4 (the paper's pull-request gate, reproduced).
#[test]
fn mlp_train_step_matches_xla() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (bsz, i, h, o) =
        (catalog::MLP_BATCH, catalog::MLP_IN, catalog::MLP_HIDDEN, catalog::MLP_OUT);
    let mut rng = Rng::new(55);
    let mut w0 = vec![0f32; i * h];
    let mut b0 = vec![0f32; h];
    let mut w1 = vec![0f32; h * o];
    let mut b1 = vec![0f32; o];
    let mut x = vec![0f32; bsz * i];
    rng.fill_uniform(&mut w0, -0.15, 0.15);
    rng.fill_uniform(&mut b0, -0.05, 0.05);
    rng.fill_uniform(&mut w1, -0.3, 0.3);
    rng.fill_uniform(&mut b1, -0.05, 0.05);
    rng.fill_uniform(&mut x, 0.0, 1.0);
    let mut y = vec![0f32; bsz * o];
    for s in 0..bsz {
        y[s * o + s % o] = 1.0;
    }

    let out = rt
        .run_f32(
            "mlp_train_step",
            &[
                (&w0[..], &[i, h][..]),
                (&b0[..], &[h][..]),
                (&w1[..], &[h, o][..]),
                (&b1[..], &[o][..]),
                (&x[..], &[bsz, i][..]),
                (&y[..], &[bsz, o][..]),
            ],
        )
        .unwrap();
    let (xw0, xb0, xw1, xb1, xloss) = (&out[0], &out[1], &out[2], &out[3], out[4][0]);

    // native: same architecture (zoo::mlp_e2e), same lr (0.5, in sync
    // with python/compile/model.py::MLP_LR)
    let mut model = ModelBuilder::new()
        .add_nodes(zoo::mlp_e2e())
        .optimizer("sgd", &[("learning_rate", "0.5")])
        .compile(&CompileOpts { batch: bsz, ..Default::default() })
        .unwrap();
    model.exec.write_weight("fc0:weight", &w0).unwrap();
    model.exec.write_weight("fc0:bias", &b0).unwrap();
    model.exec.write_weight("fc1:weight", &w1).unwrap();
    model.exec.write_weight("fc1:bias", &b1).unwrap();
    model.bind_batch(&x, &y).unwrap();
    let native_loss = model.exec.train_iteration();

    assert!(
        (native_loss - xloss).abs() / xloss.abs().max(1.0) < 1e-4,
        "loss: native {native_loss} vs xla {xloss}"
    );
    assert_close(&model.exec.read_weight("fc0:weight").unwrap(), xw0, 1e-4, "w0");
    assert_close(&model.exec.read_weight("fc0:bias").unwrap(), xb0, 1e-4, "b0");
    assert_close(&model.exec.read_weight("fc1:weight").unwrap(), xw1, 1e-4, "w1");
    assert_close(&model.exec.read_weight("fc1:bias").unwrap(), xb1, 1e-4, "b1");
}
