//! Bitwise-determinism suite for the tiered compute backend.
//!
//! The contract under test (DESIGN.md §Compute backend): for every
//! operation and every shape, `Tiered` at ANY pool width produces
//! results bitwise identical to the single-threaded `Naive` kernels.
//! The backend earns this by construction — per output element the FP
//! accumulation chain (ascending p) is the same in every regime, and
//! threading only partitions *disjoint* output elements — so these
//! tests compare with `to_bits()`, never with tolerances.

use std::sync::Arc;

use nntrainer::backend::{Backend, ComputeKind, Conv2dGeom, NaiveBackend, TieredBackend, WorkerPool};
use nntrainer::rng::Rng;

/// Pool widths every case runs at: inline (1), even split, and a width
/// that leaves remainder bands on most of the shapes below.
const WIDTHS: [usize; 3] = [1, 2, 4];

fn tiered(width: usize) -> TieredBackend {
    TieredBackend::with_pool(Arc::new(WorkerPool::new(width)))
}

fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0f32; len];
    rng.fill_uniform(&mut v, -1.0, 1.0);
    v
}

fn assert_bits(expect: &[f32], got: &[f32], what: &str) {
    assert_eq!(expect.len(), got.len(), "{what}: length mismatch");
    for (i, (e, g)) in expect.iter().zip(got.iter()).enumerate() {
        assert_eq!(
            e.to_bits(),
            g.to_bits(),
            "{what}: element {i} differs: naive {e} vs tiered {g}"
        );
    }
}

/// Which of the three GEMM entry points a case exercises.
#[derive(Clone, Copy, Debug)]
enum Op {
    Mm,
    MmAt,
    MmBt,
}

impl Op {
    /// (len_a, len_b) for C[m,n]: `MmAt` stores A as [k,m], `MmBt`
    /// stores B as [n,k].
    fn lens(self, m: usize, k: usize, n: usize) -> (usize, usize) {
        match self {
            Op::Mm => (m * k, k * n),
            Op::MmAt => (k * m, k * n),
            Op::MmBt => (m * k, n * k),
        }
    }

    fn run(self, be: &dyn Backend, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, acc: bool) {
        match self {
            Op::Mm => be.matmul(a, b, c, m, k, n, acc),
            Op::MmAt => be.matmul_at(a, b, c, m, k, n, acc),
            Op::MmBt => be.matmul_bt(a, b, c, m, k, n, acc),
        }
    }
}

/// One shape through naive and every tiered width, both accumulate
/// modes. `accumulate = true` starts from a shared random C so the
/// nonzero-c0 chain (the hard case) is what's compared.
fn check_shape(rng: &mut Rng, op: Op, m: usize, k: usize, n: usize) {
    let (la, lb) = op.lens(m, k, n);
    let a = fill(rng, la);
    let b = fill(rng, lb);
    let c0 = fill(rng, m * n);
    let naive = NaiveBackend::default();
    for acc in [false, true] {
        let mut want = if acc { c0.clone() } else { vec![0.123f32; m * n] };
        op.run(&naive, &a, &b, &mut want, m, k, n, acc);
        for width in WIDTHS {
            let be = tiered(width);
            let mut got = if acc { c0.clone() } else { vec![0.456f32; m * n] };
            op.run(&be, &a, &b, &mut got, m, k, n, acc);
            assert_bits(&want, &got, &format!("{op:?} m={m} k={k} n={n} acc={acc} width={width}"));
        }
    }
}

#[test]
fn gemm_bitwise_at_microkernel_remainders() {
    // m, n straddle the MR=4 / NR=8 tile edges (remainders 0..=3 rows,
    // 0..=7 cols); k=1 is the degenerate chain.
    let mut rng = Rng::new(0x7EED);
    for op in [Op::Mm, Op::MmAt, Op::MmBt] {
        for &m in &[3usize, 4, 5, 8, 9, 17] {
            for &n in &[3usize, 4, 5, 8, 9, 17] {
                for &k in &[1usize, 7, 64] {
                    check_shape(&mut rng, op, m, k, n);
                }
            }
        }
    }
}

#[test]
fn gemm_bitwise_at_tall_k_regime_boundary() {
    // matmul flips to the tall-K kernel at k >= 2048 (native::TALL_K_MIN_K)
    // when m*n fits the cache block; straddle the switch so both sides
    // of the branch — different accumulation chains — are compared
    // against naive taking the *same* branch.
    let mut rng = Rng::new(0x7A11);
    for op in [Op::Mm, Op::MmAt, Op::MmBt] {
        for &k in &[2047usize, 2048, 2049] {
            check_shape(&mut rng, op, 5, k, 9);
        }
    }
}

#[test]
fn gemm_bitwise_in_forced_regimes() {
    let mut rng = Rng::new(0xF0_0D);
    // forced tall-K: k >= 2048, m*n = 6400 <= CACHE_BLOCK_ELEMS
    check_shape(&mut rng, Op::Mm, 64, 2048, 100);
    // forced big-tile paths: m*n and k*n and m*k all above the cache
    // block, so every op takes its "general" branch
    for op in [Op::Mm, Op::MmAt, Op::MmBt] {
        check_shape(&mut rng, op, 300, 96, 240);
    }
}

#[test]
fn conv_implicit_gemm_bitwise_matches_materialized_im2col() {
    let geoms = [
        // square, same-padding — the common conv2d shape
        Conv2dGeom { in_c: 3, in_h: 9, in_w: 9, out_c: 5, k_h: 3, k_w: 3, stride: 1, pad_h: 1, pad_w: 1 },
        // stride 2 with asymmetric padding
        Conv2dGeom { in_c: 2, in_h: 8, in_w: 7, out_c: 4, k_h: 3, k_w: 3, stride: 2, pad_h: 1, pad_w: 0 },
        // conv1d-style degenerate height
        Conv2dGeom { in_c: 2, in_h: 1, in_w: 16, out_c: 3, k_h: 1, k_w: 5, stride: 1, pad_h: 0, pad_w: 2 },
    ];
    let mut rng = Rng::new(0xC0_4D);
    for g in &geoms {
        let batch = 3;
        let in_sz = g.in_c * g.in_h * g.in_w;
        let out_sz = g.out_c * g.col_cols();
        let x = fill(&mut rng, batch * in_sz);
        let w = fill(&mut rng, g.out_c * g.col_rows());
        let dout = fill(&mut rng, batch * out_sz);
        let gw0 = fill(&mut rng, g.out_c * g.col_rows());
        let mut col = vec![0f32; g.col_rows() * g.col_cols()];

        let naive = NaiveBackend::default();
        let mut out_naive = vec![0f32; batch * out_sz];
        naive.conv2d_forward(&x, &w, &mut out_naive, g, batch, Some(&mut col));
        let mut gw_naive = gw0.clone();
        naive.conv2d_grad_w(&x, &dout, &mut gw_naive, g, batch, Some(&mut col));

        for width in WIDTHS {
            let be = tiered(width);
            let mut out = vec![0f32; batch * out_sz];
            be.conv2d_forward(&x, &w, &mut out, g, batch, None);
            assert_bits(&out_naive, &out, &format!("conv fwd {g:?} width={width}"));
            let mut gw = gw0.clone();
            be.conv2d_grad_w(&x, &dout, &mut gw, g, batch, None);
            assert_bits(&gw_naive, &gw, &format!("conv grad_w {g:?} width={width}"));
        }
    }
}

#[test]
fn backend_instances_report_their_kind() {
    assert_eq!(ComputeKind::Tiered.instance().kind(), ComputeKind::Tiered);
    assert_eq!(ComputeKind::Naive.instance().kind(), ComputeKind::Naive);
    assert_eq!(ComputeKind::default(), ComputeKind::Tiered);
    assert!(TieredBackend::new().width() >= 1);
}

#[test]
fn flops_counter_tracks_issued_work() {
    let be = tiered(2);
    let a = vec![1f32; 6];
    let b = vec![1f32; 12];
    let mut c = vec![0f32; 8];
    be.matmul(&a, &b, &mut c, 2, 3, 4, false);
    assert_eq!(be.flops(), 2 * 2 * 3 * 4);
    be.reset_flops();
    assert_eq!(be.flops(), 0);
}
