//! The paper's worked examples as literal test fixtures.
//!
//! * Model A (Fig 4): EO table for three weighted layers.
//! * Model B (Fig 5): in-place activation — `D_1` and `X_2` not allocated.
//! * Model C (Fig 6): flatten RV-merges even with interleaved EOs.
//! * Fig 7/8: sorting-planner reuse traces and the `D_2` fragmentation
//!   case that the best-fit planner resolves.

use nntrainer::compiler::realizer::realize_all;
use nntrainer::exec::{eo_of, ideal_peak_bytes, init_graph, InitOptions};
use nntrainer::graph::{Graph, NodeDesc};
use nntrainer::layers::{builtin_factories, Props};
use nntrainer::planner::{
    validate::validate_plan, BestFitPlanner, NaivePlanner, Planner, SortingPlanner,
};
use nntrainer::tensor::TensorRole;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

fn build(nodes: Vec<NodeDesc>, opts: &InitOptions) -> nntrainer::exec::InitGraph {
    let nodes = realize_all(nodes).unwrap();
    let graph = Graph::wire(nodes).unwrap();
    init_graph(&graph, &builtin_factories(), opts).unwrap()
}

/// Fig 4 model A: in → fc → fc → fc (+ loss at the end to drive
/// backward). We check the *structure* of the EO assignment: forward EOs
/// ascend, backward EOs of layer i are 3N−2(i+1) and +1, weights span
/// [0, apply], inputs carry (F, CG), derivatives carry (CG, CD).
#[test]
fn fig4_model_a_exec_orders() {
    let n = 5; // in, fc0, fc1, fc2, loss
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:8")]),
            node("fc0", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("fc1", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("fc2", "fully_connected", &[("unit", "4"), ("bias", "false")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 2, ..Default::default() },
    );
    assert_eq!(ig.nodes.len(), n);
    // fc0 is node 1
    let eo = eo_of(1, n);
    assert_eq!((eo.f, eo.cg, eo.cd), (1, 3 * 5 - 4, 3 * 5 - 3));

    let t = &ig.table;
    // X_0 (network input): EOs {0(bind/F), F(consumer)=1, CG(fc0)=11}
    let x0 = t.get(t.by_name("in:out0").unwrap());
    assert_eq!(x0.eos, vec![0, 1, 11]);
    // X_1 = fc0 out: F(write)=1, F(fc1 read)=2, CG(fc1)=9
    let x1 = t.get(t.by_name("fc0:out0").unwrap());
    assert_eq!(x1.eos, vec![1, 2, 9]);
    // W_0: [0, eo_apply]
    let w0 = t.get(t.by_name("fc0:weight").unwrap());
    assert_eq!(w0.min_eo(), Some(0));
    assert_eq!(w0.max_eo(), Some(ig.eo_apply));
    // ΔW_0: CG(fc0)=11 .. CD(fc0)=12 (per-layer apply after CD)
    let g0 = t.get(t.by_name("fc0:weight:grad").unwrap());
    assert_eq!(g0.eos, vec![11, 12]);
    // D_1 (fc0's dout, written by fc1's CD=10, read by fc0 B=11,12)
    let d1 = t.get(t.by_name("fc0:dout0").unwrap());
    assert_eq!(d1.eos, vec![10, 11, 12]);
}

/// Fig 5 model B: the activation's output and its input-side derivative
/// are MV-merged — "D_1 and X_2 are not allocated".
#[test]
fn fig5_model_b_inplace_merges() {
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:8")]),
            node("fc0", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("act", "activation", &[("act", "sigmoid")]),
            node("fc1", "fully_connected", &[("unit", "4"), ("bias", "false")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 2, ..Default::default() },
    );
    let t = &ig.table;
    // X_2 (activation out) merged into X_1 (fc0 out)
    let x2 = t.get(t.by_name("act:out0").unwrap());
    assert!(x2.merged_into.is_some(), "activation output must MV-merge");
    assert_eq!(t.resolve(x2.id), t.by_name("fc0:out0").unwrap());
    // D_1 (fc0:dout0) merged into D_2 (act:dout0)
    let d1 = t.get(t.by_name("fc0:dout0").unwrap());
    assert!(d1.merged_into.is_some(), "in-place derivative must merge");
    assert_eq!(t.resolve(d1.id), t.by_name("act:dout0").unwrap());
}

/// Same model with `inplace: false` (the ablation): nothing merges.
#[test]
fn fig5_inplace_disabled_keeps_tensors() {
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:8")]),
            node("fc0", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("act", "activation", &[("act", "sigmoid")]),
            node("fc1", "fully_connected", &[("unit", "4"), ("bias", "false")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 2, inplace: false, ..Default::default() },
    );
    let t = &ig.table;
    assert!(t.get(t.by_name("act:out0").unwrap()).merged_into.is_none());
    assert!(t.get(t.by_name("fc0:dout0").unwrap()).merged_into.is_none());
}

/// Fig 6 model C: flatten is RV — merged even though the target's EOs
/// extend past the view's first use (integrity guaranteed by contract).
#[test]
fn fig6_model_c_readonly_view_merges() {
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:8")]),
            node("fc0", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("act", "activation", &[("act", "sigmoid")]),
            node("flat", "flatten", &[]),
            node("fc1", "fully_connected", &[("unit", "4"), ("bias", "false")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 2, ..Default::default() },
    );
    let t = &ig.table;
    let flat_out = t.get(t.by_name("flat:out0").unwrap());
    assert!(flat_out.merged_into.is_some(), "flatten output must RV-merge");
    // chain resolves through the activation merge to fc0's output
    assert_eq!(t.resolve(flat_out.id), t.by_name("fc0:out0").unwrap());
    // flatten's derivative side merges too
    let act_dout = t.get(t.by_name("act:dout0").unwrap());
    assert!(act_dout.merged_into.is_some());
}

/// MV merge must be *refused* when the target is still live after the
/// view's first write (Algorithm 1 line 17's integrity check) — the
/// view is demoted to a fresh tensor instead.
#[test]
fn mv_integrity_demotion() {
    // fc0's output feeds BOTH an activation (wants MV) and, via multiout,
    // a second consumer that reads it later — the merge would corrupt it.
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:8")]),
            node("fc0", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("act", "activation", &[("act", "sigmoid"), ("input_layers", "fc0")]),
            node("fc_a", "fully_connected", &[("unit", "4"), ("bias", "false"), ("input_layers", "act")]),
            node("fc_b", "fully_connected", &[("unit", "4"), ("bias", "false"), ("input_layers", "fc0")]),
            node("add", "addition", &[("input_layers", "fc_a,fc_b")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 2, ..Default::default() },
    );
    let t = &ig.table;
    // multiout realizer fans fc0 out; the activation's input is a
    // multiout branch. The branch copies are fresh tensors, so the MV
    // merge is onto the branch — fc0:out0 itself must stay intact.
    let fc0_out = t.get(t.by_name("fc0:out0").unwrap());
    assert!(fc0_out.merged_into.is_none());
    // validate the plan end-to-end for good measure
    let mut table = ig.table;
    let len = SortingPlanner.plan(&mut table).unwrap();
    validate_plan(&table, len).unwrap();
}

/// Fig 7: the sorting planner reuses slots — pool must be well below the
/// naive sum, and ≥ the analytic ideal.
#[test]
fn fig7_sorting_planner_reuses() {
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:64")]),
            node("fc0", "fully_connected", &[("unit", "64"), ("bias", "false")]),
            node("fc1", "fully_connected", &[("unit", "64"), ("bias", "false")]),
            node("fc2", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 16, ..Default::default() },
    );
    let ideal = ideal_peak_bytes(&ig.table);

    let mut t_naive = ig.table.clone();
    let naive = NaivePlanner.plan(&mut t_naive).unwrap() * 4;
    let mut t_sort = ig.table.clone();
    let sorted = SortingPlanner.plan(&mut t_sort).unwrap() * 4;
    validate_plan(&t_sort, sorted / 4).unwrap();

    assert!(sorted < naive, "sorting {sorted} !< naive {naive}");
    assert!(sorted >= ideal, "sorting {sorted} < ideal {ideal}?!");
    // the planner should be within 2x of ideal on this simple chain
    assert!(sorted <= ideal * 2, "sorting {sorted} vs ideal {ideal}");
}

/// Fig 8: fragmentation — best-fit (slot splitting) never exceeds the
/// sorting planner, and both are validated.
#[test]
fn fig8_bestfit_not_worse() {
    for nodes in [
        vec![
            node("in", "input", &[("input_shape", "1:1:256")]),
            node("fc0", "fully_connected", &[("unit", "32"), ("bias", "false")]),
            node("act", "activation", &[("act", "sigmoid")]),
            node("fc1", "fully_connected", &[("unit", "128"), ("bias", "false")]),
            node("fc2", "fully_connected", &[("unit", "8"), ("bias", "false")]),
            node("loss", "mse", &[]),
        ],
        vec![
            node("in", "input", &[("input_shape", "2:16:16")]),
            node("c0", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
            node("p0", "pooling2d", &[("pooling", "max"), ("pool_size", "2")]),
            node("flat", "flatten", &[]),
            node("fc", "fully_connected", &[("unit", "10")]),
            node("loss", "cross_entropy", &[]),
        ],
    ] {
        let ig = build(nodes, &InitOptions { batch: 8, ..Default::default() });
        let mut t_sort = ig.table.clone();
        let sorted = SortingPlanner.plan(&mut t_sort).unwrap();
        validate_plan(&t_sort, sorted).unwrap();
        let mut t_best = ig.table.clone();
        let best = BestFitPlanner.plan(&mut t_best).unwrap();
        validate_plan(&t_best, best).unwrap();
        assert!(best <= sorted, "bestfit {best} > sorting {sorted}");
    }
}

/// Inference mode drops derivatives and gradients entirely (paper §3:
/// "We can drop a significant part of buffers for inference").
#[test]
fn inference_mode_drops_backward_tensors() {
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:32")]),
            node("fc0", "fully_connected", &[("unit", "32"), ("activation", "sigmoid")]),
            node("fc1", "fully_connected", &[("unit", "8")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 4, training: false, ..Default::default() },
    );
    for s in ig.table.iter() {
        assert!(
            !matches!(s.role, TensorRole::Derivative | TensorRole::Gradient),
            "inference graph contains {} ({})",
            s.name,
            s.role
        );
    }
}

/// Frozen-backbone pruning: layers before the first trainable layer get
/// no derivative buffers at all (transfer-learning memory claim, Fig 12).
#[test]
fn frozen_backbone_prunes_derivatives() {
    let ig = build(
        vec![
            node("in", "input", &[("input_shape", "1:1:32")]),
            node("frozen0", "fully_connected", &[("unit", "32"), ("trainable", "false")]),
            node("frozen1", "fully_connected", &[("unit", "32"), ("trainable", "false")]),
            node("head", "fully_connected", &[("unit", "8")]),
            node("loss", "mse", &[]),
        ],
        &InitOptions { batch: 4, ..Default::default() },
    );
    let t = &ig.table;
    assert!(t.by_name("frozen0:dout0").is_none());
    assert!(t.by_name("frozen1:weight:grad").is_none());
    // head's input derivative exists only if an ancestor trains; none does
    assert!(t.by_name("frozen1:dout0").is_none());
    // the head itself still trains
    assert!(t.by_name("head:weight:grad").is_some());
}
