//! Calibration property tests: the lead/depth derivation is pure
//! arithmetic over a measured store speed and a per-EO cost model, so
//! its invariants can be hammered with synthetic calibrations and
//! seeded random offload plans — no wall clock involved:
//!
//! * leads are monotone in tensor size (more bytes ⇒ never a shorter
//!   lead) and inversely monotone in bandwidth (a faster store ⇒ never
//!   a longer lead)
//! * a lead never swallows its idle gap and never drops below the
//!   fixed default
//! * depth is clamped to `[2, entries]` and inversely monotone in
//!   bandwidth
//! * calibrated plans still place and validate through the gap-aware
//!   planner (the planner, validator and runtime share the per-entry
//!   lead model), and their advised peak accounts for the widened
//!   residency (never below the fixed-lead peak)

use nntrainer::planner::offload::{advise, peak_of_plan, OffloadPlan, PREFETCH_LEAD};
use nntrainer::planner::validate::validate_gap_plan;
use nntrainer::planner::{GapFitPlanner, Planner};
use nntrainer::rng::Rng;
use nntrainer::runtime::calibrate::{derive_depth, derive_leads, lead_for};
use nntrainer::runtime::{EoCostModel, StoreCalibration};
use nntrainer::tensor::{
    CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable,
};

const EO_SPAN: u32 = 48;

/// Random activation-heavy table (the advisor's candidate population).
fn random_table(rng: &mut Rng) -> TensorTable {
    let mut t = TensorTable::new();
    let n = 3 + rng.below(14);
    for i in 0..n {
        let role = match rng.below(4) {
            0 => TensorRole::Temp,
            1 => TensorRole::Derivative,
            _ => TensorRole::Activation,
        };
        let len = 1 + rng.below(2048);
        let id = t
            .request(
                format!("t{i}"),
                TensorDim::vec(1, len),
                role,
                CreateMode::Create,
                Initializer::None,
            )
            .unwrap();
        let uses = 2 + rng.below(4);
        for _ in 0..uses {
            t.add_eo(id, rng.below(EO_SPAN as usize) as u32, Lifespan::FORWARD);
        }
    }
    t.finish_orders();
    t
}

#[test]
fn leads_monotone_in_size_and_inverse_in_bandwidth() {
    let cost = EoCostModel::uniform(EO_SPAN as usize, 1_000.0);
    let bandwidths = [1.0, 10.0, 100.0, 1000.0]; // MB/s
    let sizes = [64usize, 1 << 10, 1 << 14, 1 << 18, 1 << 22]; // bytes
    for (evict, prefetch) in [(0u32, 40u32), (3, 20), (10, 46)] {
        for &mbps in &bandwidths {
            let store = StoreCalibration::synthetic(mbps);
            let mut prev = 0u32;
            for &bytes in &sizes {
                let lead = lead_for(bytes, evict, prefetch, &store, &cost);
                assert!(
                    lead >= prev,
                    "lead shrank as size grew: {bytes}B @ {mbps}MB/s → {lead} < {prev}"
                );
                assert!(lead >= PREFETCH_LEAD, "lead below the fixed default");
                assert!(
                    evict + lead < prefetch,
                    "lead {lead} swallows gap ({evict}, {prefetch})"
                );
                prev = lead;
            }
        }
        for &bytes in &sizes {
            let mut prev = u32::MAX;
            for &mbps in &bandwidths {
                let store = StoreCalibration::synthetic(mbps);
                let lead = lead_for(bytes, evict, prefetch, &store, &cost);
                assert!(
                    lead <= prev,
                    "lead grew as bandwidth grew: {bytes}B @ {mbps}MB/s → {lead} > {prev}"
                );
                prev = lead;
            }
        }
    }
}

#[test]
fn depth_clamped_and_inverse_in_bandwidth() {
    let mut rng = Rng::new(20260731);
    for case in 0..100 {
        let t = random_table(&mut rng);
        let full = advise(&t, usize::MAX).primary_peak_bytes;
        let plan = advise(&t, full / 2);
        if plan.entries.is_empty() {
            continue;
        }
        let cost = EoCostModel::uniform(EO_SPAN as usize, 1_000.0);
        let mut prev = usize::MAX;
        for mbps in [0.1, 1.0, 100.0, 1e6] {
            let d = derive_depth(&plan, &StoreCalibration::synthetic(mbps), &cost);
            assert!(
                (2..=plan.entries.len().max(2)).contains(&d),
                "case {case}: depth {d} outside [2, {}]",
                plan.entries.len()
            );
            assert!(d <= prev, "case {case}: depth grew with bandwidth");
            prev = d;
        }
    }
}

/// Calibrated leads feed the same liveness model as the planner and the
/// validator: every derived plan must still realize into a validated
/// layout, and the refreshed peak must cover the widened residency.
#[test]
fn calibrated_plans_place_and_validate() {
    let mut rng = Rng::new(777);
    let cost = EoCostModel::uniform(EO_SPAN as usize, 1_000.0);
    for case in 0..100 {
        let mut t = random_table(&mut rng);
        let full = advise(&t, usize::MAX).primary_peak_bytes;
        let budget = match case % 3 {
            0 => full / 2,
            1 => full / 4,
            _ => 1,
        };
        let mut plan: OffloadPlan = advise(&t, budget);
        let fixed_peak = plan.primary_peak_bytes;
        // a store slow enough to stretch most leads to their caps
        let store = StoreCalibration::synthetic(0.05 + (case % 7) as f64);
        derive_leads(&mut plan, &t, budget, &store, &cost);
        for e in &plan.entries {
            assert!(e.lead >= PREFETCH_LEAD);
            assert!(
                e.evict_after + e.lead < e.prefetch_before,
                "case {case}: `{}` lead {} swallows gap ({}, {})",
                e.name,
                e.lead,
                e.evict_after,
                e.prefetch_before
            );
        }
        assert!(
            plan.primary_peak_bytes >= fixed_peak,
            "case {case}: widened leads shrank the advised peak"
        );
        assert_eq!(plan.primary_peak_bytes, peak_of_plan(&t, &plan));
        assert_eq!(plan.fits, plan.primary_peak_bytes <= budget);
        assert!(plan.prefetch_depth >= 2);

        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        assert!(
            pool_len * 4 >= plan.primary_peak_bytes,
            "case {case}: pool below the analytic bound"
        );
    }
}

/// Write-lead derivation properties: monotone in size, inverse in
/// bandwidth, always inside the cap left by the read lead, and the
/// combined pair never swallows any gap `derive_leads` produces.
#[test]
fn write_leads_bounded_and_monotone() {
    use nntrainer::runtime::calibrate::{write_lead_cap, write_lead_for_ns};

    let cost = EoCostModel::uniform(EO_SPAN as usize, 1_000.0);
    let bandwidths = [1.0, 10.0, 100.0, 1000.0]; // MB/s
    let sizes = [64usize, 1 << 10, 1 << 14, 1 << 18, 1 << 22]; // bytes
    for (evict, prefetch, rlead) in [(0u32, 40u32, 1u32), (3, 20, 4), (10, 46, 2)] {
        for &mbps in &bandwidths {
            let store = StoreCalibration::synthetic(mbps);
            let mut prev = 0u32;
            for &bytes in &sizes {
                let w = write_lead_for_ns(store.evict_ns(bytes), evict, prefetch, rlead, &cost);
                assert!(w >= prev, "write lead shrank as size grew: {bytes}B → {w} < {prev}");
                assert!(
                    w <= write_lead_cap(evict, prefetch, rlead),
                    "write lead {w} past the cap for gap ({evict}, {prefetch}) rlead {rlead}"
                );
                assert!(
                    evict + rlead + w < prefetch,
                    "write lead {w} + read lead {rlead} swallow gap ({evict}, {prefetch})"
                );
                prev = w;
            }
        }
        for &bytes in &sizes {
            let mut prev = u32::MAX;
            for &mbps in &bandwidths {
                let store = StoreCalibration::synthetic(mbps);
                let w = write_lead_for_ns(store.evict_ns(bytes), evict, prefetch, rlead, &cost);
                assert!(w <= prev, "write lead grew as bandwidth grew");
                prev = w;
            }
        }
    }
}

/// `derive_leads` write side over random advisor plans: every entry's
/// pair respects the gap, and the end-extended residency still places
/// and validates through the gap-aware planner.
#[test]
fn derived_write_leads_place_and_validate() {
    let mut rng = Rng::new(4242);
    let cost = EoCostModel::uniform(EO_SPAN as usize, 1_000.0);
    for case in 0..100 {
        let mut t = random_table(&mut rng);
        let full = advise(&t, usize::MAX).primary_peak_bytes;
        let budget = if case % 2 == 0 { full / 3 } else { 1 };
        let mut plan: OffloadPlan = advise(&t, budget);
        if plan.entries.is_empty() {
            continue;
        }
        // an asymmetric store: writes much slower than reads, so write
        // leads stretch while read leads stay narrow
        let store = StoreCalibration {
            write_bps: 0.2e6,
            read_bps: 500e6,
            per_op_ns: 0.0,
        };
        derive_leads(&mut plan, &t, budget, &store, &cost);
        for e in &plan.entries {
            assert!(
                e.evict_after + e.lead + e.write_lead < e.prefetch_before,
                "case {case}: `{}` leads ({}, {}) swallow gap ({}, {})",
                e.name,
                e.lead,
                e.write_lead,
                e.evict_after,
                e.prefetch_before
            );
        }
        assert_eq!(plan.primary_peak_bytes, peak_of_plan(&t, &plan));
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        validate_gap_plan(&t, &plan, pool_len).unwrap();
    }
}
