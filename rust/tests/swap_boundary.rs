//! Cross-iteration (boundary) swap pipeline suite:
//!
//! * a boundary restore whose address range is covered by a *carried*
//!   in-flight eviction write must **wait** the write out (boundary
//!   hazard), never corrupt either side — and the carried round trip is
//!   bitwise;
//! * a failing restore in the `end_iteration` sweep must propagate the
//!   *original* store error after draining every transfer — the next
//!   `begin_iteration` starts clean instead of masking it with "stale
//!   transfers at iteration start";
//! * a not-yet-writable entry at the head of the prefetch queue must
//!   not starve later-deadline entries of their background fetches
//!   (prefetch head-of-line blocking);
//! * model-level: training with `swap_pipeline` on is bitwise identical
//!   to the unswapped model, carries state across `end_iteration`, and
//!   fully drains on `quiesce_swap`.

use std::time::Duration;

use nntrainer::compiler::CompileOpts;
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{Model, ModelBuilder};
use nntrainer::planner::offload::{advise, OffloadEntry, OffloadPlan, PREFETCH_DEPTH};
use nntrainer::planner::MemoryPool;
use nntrainer::rng::Rng;
use nntrainer::runtime::{HostStore, SecondaryStore, SwapExec};
use nntrainer::tensor::{
    CreateMode, Initializer, Lifespan, Region, Residency, TensorDim, TensorRole, TensorTable,
};

/// Host store with per-key fault/latency injection: `put` sleeps
/// `put_delay` for keys in `slow_put_keys`; `get` sleeps `get_delay`
/// for keys in `slow_get_keys` and fails (once per charge) for keys
/// with charges in `fail_gets`.
#[derive(Default)]
struct FaultStore {
    inner: HostStore,
    slow_put_keys: Vec<usize>,
    put_delay: Duration,
    slow_get_keys: Vec<usize>,
    get_delay: Duration,
    /// `(key, remaining failures)` — decremented per injected failure.
    fail_gets: Vec<(usize, usize)>,
}

impl SecondaryStore for FaultStore {
    fn kind(&self) -> &'static str {
        "fault-host"
    }
    fn put(&mut self, key: usize, data: &[f32]) -> nntrainer::Result<()> {
        if self.slow_put_keys.contains(&key) {
            std::thread::sleep(self.put_delay);
        }
        self.inner.put(key, data)
    }
    fn get(&mut self, key: usize, out: &mut [f32]) -> nntrainer::Result<()> {
        if let Some(slot) = self.fail_gets.iter_mut().find(|(k, n)| *k == key && *n > 0) {
            slot.1 -= 1;
            return Err(nntrainer::Error::Runtime(format!(
                "injected get failure for slot {key}"
            )));
        }
        if self.slow_get_keys.contains(&key) {
            std::thread::sleep(self.get_delay);
        }
        self.inner.get(key, out)
    }
    fn free(&mut self, key: usize) {
        self.inner.free(key);
    }
    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }
}

fn entry(tensor: usize, name: &str, bytes: usize, ea: u32, pb: u32, wrap: bool) -> OffloadEntry {
    OffloadEntry {
        tensor,
        name: name.into(),
        bytes,
        evict_after: ea,
        prefetch_before: pb,
        lead: 1,
        write_lead: 0,
        wrap,
    }
}

fn plan_of(entries: Vec<OffloadEntry>, peak: usize) -> OffloadPlan {
    let swap_bytes = entries.iter().map(|e| 2 * e.bytes).sum();
    OffloadPlan {
        entries,
        primary_peak_bytes: peak,
        swap_bytes_per_iter: swap_bytes,
        fits: true,
        prefetch_depth: PREFETCH_DEPTH,
    }
}

fn manual_tensor(
    t: &mut TensorTable,
    name: &str,
    len: usize,
    eos: &[u32],
    region: Region,
) -> usize {
    let id = t
        .request(name, TensorDim::vec(1, len), TensorRole::Weight, CreateMode::Create, Initializer::None)
        .unwrap();
    for &e in eos {
        t.add_eo(id, e, Lifespan::FORWARD);
    }
    t.get_mut(id).region = Some(region);
    id
}

fn drive_iteration(sw: &mut SwapExec, pool: &MemoryPool, last_eo: u32) {
    sw.begin_iteration(true, pool).unwrap();
    for eo in 0..=last_eo {
        sw.pre_step(eo, pool).unwrap();
        sw.post_step(eo, pool).unwrap();
    }
    sw.end_iteration(pool).unwrap();
}

// ------------------------------------------------- boundary write hazard

/// Two wrap entries on overlapping address ranges: `a` lives late in the
/// schedule (EOs 4..6, slow carried eviction write), `c` early (EOs
/// 1..2, restore barrier at EO 0). Iteration N+1's restore of `c`
/// reacquires addresses `a`'s *carried* iteration-N eviction write is
/// still reading — the schedule-head write barrier must wait the write
/// out (write stall accrues) and both tensors' bytes must round-trip
/// bitwise.
#[test]
fn boundary_restore_waits_out_carried_overlapping_write() {
    let len = 256usize;
    let pool_len = 384usize;
    let mut t = TensorTable::new();
    // a: [0, 256) — carried eviction at EO 6 (schedule end), restore at 4
    let a = manual_tensor(&mut t, "a", len, &[4, 6], Region { offset: 0, len });
    // c: [128, 384) — evicted at EO 2, restore barrier at EO 0 (due)
    let c = manual_tensor(&mut t, "c", len, &[1, 2], Region { offset: 128, len });
    t.finish_orders();
    let plan = plan_of(
        vec![
            entry(a, "a", len * 4, 6, 4, true), // key 0: slow put
            entry(c, "c", len * 4, 2, 1, true), // key 1: fast
        ],
        pool_len * 4,
    );
    let store = FaultStore {
        slow_put_keys: vec![0],
        put_delay: Duration::from_millis(120),
        ..Default::default()
    };
    let pool = MemoryPool::new(pool_len);
    let mut sw = SwapExec::new(&t, &plan, Box::new(store), None).unwrap();
    assert_eq!(sw.n_wrap_entries(), 2);
    assert!(sw.is_wrap(0) && sw.is_wrap(1));
    // a has a schedule-head tenant (c's restore at EO 0): the carried
    // write's completion barrier sits at the very first step
    assert_eq!(sw.head_reclaim_eo_of(0), 0);

    let full = Region { offset: 0, len: pool_len };
    let pattern: Vec<f32> = (0..pool_len).map(|i| (i as f32) * 0.25 - 11.5).collect();
    pool.view_mut(full).copy_from_slice(&pattern);

    // iteration N: both entries evict; a's write is slow and carries
    drive_iteration(&mut sw, &pool, 6);
    assert!(
        sw.has_carried_state(),
        "boundary evictions must carry across end_iteration"
    );

    // iteration N+1: the EO-0 write barrier covers a's in-flight write
    sw.begin_iteration(true, &pool).unwrap();
    let stall0 = sw.stats.write_stall_ns;
    sw.pre_step(0, &pool).unwrap();
    assert!(
        sw.stats.write_stall_ns > stall0,
        "restore over a carried in-flight write must wait it out, got {:?}",
        sw.stats
    );
    // c is back, bitwise, despite the overlap with a's eviction
    for (k, (x, y)) in pool.view(Region { offset: 128, len }).iter().zip(&pattern[128..]).enumerate()
    {
        assert_eq!(x.to_bits(), y.to_bits(), "c[{k}] corrupted: {x} vs {y}");
    }
    for eo in 0..=6 {
        if eo > 0 {
            sw.pre_step(eo, &pool).unwrap();
        }
        if eo == 3 {
            // a's restore barrier (due = 4 - 1) has completed: its full
            // range carries the original bytes
            for (k, (x, y)) in
                pool.view(Region { offset: 0, len }).iter().zip(&pattern[..len]).enumerate()
            {
                assert_eq!(x.to_bits(), y.to_bits(), "a[{k}] corrupted: {x} vs {y}");
            }
        }
        sw.post_step(eo, &pool).unwrap();
    }
    sw.end_iteration(&pool).unwrap();
    assert!(sw.has_carried_state());

    // mandatory full drain: everything lands back in the pool
    sw.quiesce(&pool).unwrap();
    assert!(!sw.has_carried_state(), "quiesce must clear carried state");
    for (k, (x, y)) in pool.view(full).iter().zip(&pattern).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "pool[{k}] after quiesce: {x} vs {y}");
    }
    assert!(sw.stats.boundary_stall_ns <= sw.stats.read_stall_ns);
    // 6 each: the first begin_iteration primes both wrap entries (2
    // evictions), each iteration evicts both (2×2), and every eviction
    // is matched by a restore (2 per iteration + 2 at quiesce).
    assert_eq!(sw.stats.evictions, 6);
    assert_eq!(sw.stats.prefetches, 6);
}

// ------------------------------------- end_iteration error propagation

/// A store failure surfacing in the `end_iteration` restore sweep used
/// to return early with other transfers still in flight: the *next*
/// `begin_iteration` then failed with "stale transfers at iteration
/// start", masking the real error. The sweep must drain everything and
/// propagate the original failure — and the engine must start the next
/// iteration clean.
#[test]
fn end_iteration_failure_propagates_original_error_and_drains() {
    let len = 64usize;
    let mut t = TensorTable::new();
    let a = manual_tensor(&mut t, "a", len, &[0, 6], Region { offset: 0, len });
    let b = manual_tensor(&mut t, "b", len, &[1, 7], Region { offset: len, len });
    t.finish_orders();
    let plan = plan_of(
        vec![entry(a, "a", len * 4, 0, 6, false), entry(b, "b", len * 4, 1, 7, false)],
        2 * len * 4,
    );
    let store = FaultStore {
        // a's first restore fails; b's restore is slow enough to still be
        // in flight when the sweep hits a's error
        fail_gets: vec![(0, 1)],
        slow_get_keys: vec![1],
        get_delay: Duration::from_millis(80),
        ..Default::default()
    };
    let pool = MemoryPool::new(2 * len);
    let mut sw = SwapExec::new(&t, &plan, Box::new(store), None).unwrap();

    sw.begin_iteration(true, &pool).unwrap();
    // partial pass: both entries evict, neither reaches its restore
    // barrier — the end-of-iteration sweep does the restores
    for eo in 0..=3 {
        sw.pre_step(eo, &pool).unwrap();
        sw.post_step(eo, &pool).unwrap();
    }
    let err = sw.end_iteration(&pool).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("injected get failure"),
        "the original store error must propagate, got: {msg}"
    );

    // the regression: the engine drained everything before propagating,
    // so the next iteration starts clean instead of erroring with
    // "stale transfers at iteration start"
    sw.begin_iteration(true, &pool)
        .expect("begin_iteration after a drained end_iteration failure");
    // and a full iteration now runs end-to-end (the injected failure was
    // single-shot)
    for eo in 0..=7 {
        sw.pre_step(eo, &pool).unwrap();
        sw.post_step(eo, &pool).unwrap();
    }
    sw.end_iteration(&pool).unwrap();
}

// ------------------------------------------- prefetch head-of-line fix

/// A not-yet-writable entry at the head of the prefetch queue (its
/// eviction still ahead, its store slot not yet written) used to block
/// every later-deadline entry's background fetch — they all fell back
/// to inline sync fetches at their barriers. The pump must skip over
/// the unready head and issue the ready entry behind it.
#[test]
fn unready_queue_head_does_not_starve_later_fetches() {
    let len = 64usize;
    let mut t = TensorTable::new();
    // e0 heads the queue (due 5) but evicts late (EO 2) with a slow
    // write; e1 (due 7) evicts at EO 0 and its write lands immediately
    let t0 = manual_tensor(&mut t, "t0", len, &[2, 6], Region { offset: 0, len });
    let t1 = manual_tensor(&mut t, "t1", len, &[0, 8], Region { offset: len, len });
    t.finish_orders();
    let plan = plan_of(
        vec![entry(t0, "t0", len * 4, 2, 6, false), entry(t1, "t1", len * 4, 0, 8, false)],
        2 * len * 4,
    );
    let store = FaultStore {
        slow_put_keys: vec![0],
        put_delay: Duration::from_millis(100),
        ..Default::default()
    };
    let pool = MemoryPool::new(2 * len);
    let mut sw = SwapExec::new(&t, &plan, Box::new(store), None).unwrap();

    sw.begin_iteration(true, &pool).unwrap();
    sw.pre_step(0, &pool).unwrap();
    sw.post_step(0, &pool).unwrap(); // e1 evicts; its write ticket lands fast
    std::thread::sleep(Duration::from_millis(20));
    sw.pre_step(1, &pool).unwrap();
    sw.post_step(1, &pool).unwrap(); // drain observes e1's write; pump runs
    assert_eq!(
        sw.residency_of(t1),
        Some(Residency::Fetching),
        "the pump must skip the unready queue head and issue t1's fetch"
    );
    for eo in 2..=8 {
        sw.pre_step(eo, &pool).unwrap();
        sw.post_step(eo, &pool).unwrap();
    }
    sw.end_iteration(&pool).unwrap();
    // only e0 (whose own write really was slow) fell back to an inline
    // fetch at its barrier; pre-fix both did
    assert_eq!(sw.stats.sync_fetches, 1, "{:?}", sw.stats);
    assert!(
        sw.observed_fetch_ns(1) > 0.0,
        "t1's fetch must have completed in the background"
    );
    assert_eq!(sw.observed_fetch_ns(0), 0.0);
}

// -------------------------------------------- model-level equivalence

fn conv_stack() -> Vec<NodeDesc> {
    let node = |name: &str, ltype: &str, pairs: &[(&str, &str)]| {
        NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
    };
    vec![
        node("in", "input", &[("input_shape", "4:12:12")]),
        node("c0", "conv2d", &[("filters", "8"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "8"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("fc", "fully_connected", &[("unit", "10")]),
        node("loss", "mse", &[]),
    ]
}

fn compile(batch: usize, budget: Option<usize>, pipeline: bool) -> Model {
    ModelBuilder::new()
        .add_nodes(conv_stack())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .compile(&CompileOpts {
            batch,
            memory_budget_bytes: budget,
            swap_pipeline: pipeline,
            ..Default::default()
        })
        .unwrap()
}

fn io_lens(m: &Model) -> (usize, usize) {
    let in_len = m
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len = m
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    (in_len, lb_len)
}

/// The acceptance gate: training under a budget with cross-iteration
/// pipelining on — persistent tensors streaming through the store
/// across iteration boundaries — is bitwise identical to the unswapped
/// model, while actually carrying transfers across `end_iteration`.
#[test]
fn pipelined_training_is_bitwise_identical_to_unswapped() {
    let batch = 8usize;
    let full = advise(&compile(batch, None, false).exec.graph.table, usize::MAX)
        .primary_peak_bytes;
    let mut base = compile(batch, None, false);
    let mut piped = compile(batch, Some(full * 75 / 100), true);
    assert!(
        piped.exec.swap_n_wrap_entries().unwrap_or(0) > 0,
        "swap_pipeline under per-layer apply must plan wrap entries"
    );

    let (in_len, lb_len) = io_lens(&base);
    let mut rng = Rng::new(0xB0B0);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    let mut carried_seen = false;
    for it in 0..4 {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        base.bind_batch(&input, &label).unwrap();
        piped.bind_batch(&input, &label).unwrap();
        let l0 = base.exec.try_train_iteration().unwrap();
        let l1 = piped.exec.try_train_iteration().unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "iteration {it}: {l0} vs {l1}");
        carried_seen |= piped
            .exec
            .swap_mut()
            .map(|sw| sw.has_carried_state())
            .unwrap_or(false);
    }
    assert!(
        carried_seen,
        "the pipeline never carried a boundary transfer across end_iteration"
    );

    // run end is a mandatory full-drain point: quiesce, then the pool is
    // the source of truth for every weight
    piped.exec.quiesce_swap().unwrap();
    assert!(!piped.exec.swap_mut().unwrap().has_carried_state());
    for w in base.exec.weight_names() {
        let x = base.exec.read_weight(&w).unwrap();
        let y = piped.exec.read_weight(&w).unwrap();
        for (k, (p, q)) in x.iter().zip(y.iter()).enumerate() {
            assert_eq!(p.to_bits(), q.to_bits(), "{w}[{k}]: {p} vs {q}");
        }
    }
}

/// The drained-boundary baseline (`set_boundary_drain`) is bitwise
/// identical too — it only moves *when* the boundary copies happen (the
/// switch the bench's pipelined-vs-drained rows rely on).
#[test]
fn boundary_drain_mode_is_bitwise_identical() {
    let batch = 8usize;
    let full = advise(&compile(batch, None, false).exec.graph.table, usize::MAX)
        .primary_peak_bytes;
    let budget = Some(full * 75 / 100);
    let mut piped = compile(batch, budget, true);
    let mut drained = compile(batch, budget, true);
    drained.exec.swap_mut().unwrap().set_boundary_drain(true);

    let (in_len, lb_len) = io_lens(&piped);
    let mut rng = Rng::new(0xD1CE);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    for it in 0..3 {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        piped.bind_batch(&input, &label).unwrap();
        drained.bind_batch(&input, &label).unwrap();
        let l0 = piped.exec.try_train_iteration().unwrap();
        let l1 = drained.exec.try_train_iteration().unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "iteration {it}: {l0} vs {l1}");
    }
    assert!(
        !drained.exec.swap_mut().unwrap().has_carried_state(),
        "the drained baseline must not carry state across end_iteration"
    );
    piped.exec.quiesce_swap().unwrap();
    for w in piped.exec.weight_names() {
        let x = piped.exec.read_weight(&w).unwrap();
        let y = drained.exec.read_weight(&w).unwrap();
        for (k, (p, q)) in x.iter().zip(y.iter()).enumerate() {
            assert_eq!(p.to_bits(), q.to_bits(), "{w}[{k}]: {p} vs {q}");
        }
    }
}
