//! Fleet-service suite: the multi-tenant service must be *invisible* to
//! a tenant — training through `FleetService` (with context switches,
//! parking round-trips through a real store, and interleaved strangers)
//! produces weights bitwise identical to the same seed trained via a
//! standalone `CompiledSession::personalize`. Plus: the admission
//! arithmetic, the isolation invariant, and store-slot hygiene on
//! departure.

use nntrainer::dataset::producer::{CachedProducer, Sample};
use nntrainer::dataset::DataProducer;
use nntrainer::fleet::{FleetConfig, FleetService, TenantSpec, TenantState};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{DeviceProfile, PersonalizeOpts, Session, TrainSpec};
use nntrainer::rng::Rng;
use nntrainer::runtime::StoreKind;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

/// Conv backbone (`c0`, `c1`) + fc head (`head`) — the same
/// freeze/personalize fixture `session_api.rs` uses.
fn conv_net() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "2:8:8")]),
        node("c0", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("head", "fully_connected", &[("unit", "6")]),
        node("loss", "mse", &[]),
    ]
}

const OPT: (&str, &[(&str, &str)]) =
    ("sgd", &[("learning_rate", "0.05"), ("momentum", "0.9")]);

fn frozen_spec(batch: usize, epochs: usize) -> TrainSpec {
    TrainSpec {
        batch: Some(batch),
        epochs,
        freeze: vec!["c0".into(), "c1".into()],
        ..Default::default()
    }
}

/// Fixed per-tenant dataset: deterministic in (tenant seed, index), the
/// index-determinism the fleet requires of producers.
fn tenant_samples(seed: u64, n: usize, in_len: usize, lb_len: usize) -> Vec<Sample> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut input = vec![0f32; in_len];
            let mut label = vec![0f32; lb_len];
            rng.fill_uniform(&mut input, -1.0, 1.0);
            rng.fill_uniform(&mut label, 0.0, 1.0);
            Sample { input, label }
        })
        .collect()
}

fn vendor_checkpoint(tag: &str) -> (String, usize, usize) {
    let mut vendor = Session::describe(conv_net())
        .optimizer(OPT.0, OPT.1)
        .configure(TrainSpec { batch: Some(4), epochs: 2, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let exec = &vendor.model.exec;
    let in_len: usize = exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len: usize = exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    let samples = tenant_samples(0xFEED, 16, in_len, lb_len);
    let make = move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(samples.clone())) };
    vendor.train(&make).unwrap();
    let path = std::env::temp_dir()
        .join(format!("fleet_service_{tag}_{}.nntr", std::process::id()))
        .to_string_lossy()
        .into_owned();
    vendor.save(&path).unwrap();
    (path, in_len, lb_len)
}

/// Probe the fleet's memory arithmetic with an unconstrained budget so
/// tests can then build a *tight* budget from real numbers.
fn probe_plan(ckpt: &str) -> (usize, usize) {
    let fleet = FleetService::build(
        conv_net(),
        OPT.0,
        OPT.1,
        frozen_spec(4, 1),
        DeviceProfile::unconstrained(),
        FleetConfig {
            checkpoint: Some(ckpt.to_string()),
            ..FleetConfig::new(usize::MAX / 2, vec!["head".into()])
        },
    )
    .unwrap();
    let plan = fleet.admission();
    (plan.shared_pool_bytes, plan.tenant_state_bytes)
}

// ---------------------------------------------------- bitwise equivalence

/// The acceptance gate: a tenant trained through the fleet — context-
/// switched every 2 steps, parked through a *file* store under a budget
/// that keeps only one state copy in RAM, interleaved with two strangers
/// — ends bitwise identical (head weights AND optimizer momentum) to the
/// same seed trained alone via `CompiledSession::personalize`.
#[test]
fn fleet_tenant_is_bitwise_equal_to_standalone_personalize() {
    let (ckpt, in_len, lb_len) = vendor_checkpoint("equiv");
    let batch = 4usize;
    let epochs = 3usize;
    let matched_seed = 0xA11CE_u64;

    // -- standalone reference ------------------------------------------
    let mut standalone = Session::describe(conv_net())
        .optimizer(OPT.0, OPT.1)
        .configure(frozen_spec(batch, epochs))
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let data = tenant_samples(matched_seed ^ 0xDA7A, 16, in_len, lb_len);
    let mk = data.clone();
    let make = move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(mk.clone())) };
    standalone
        .personalize(
            &PersonalizeOpts {
                checkpoint: Some(ckpt.clone()),
                reinit: vec!["head".into()],
                reinit_seed: matched_seed,
                ..Default::default()
            },
            &make,
            &mut [],
        )
        .unwrap();
    let layout = standalone.head_state_layout(&["head".into()]).unwrap();
    let mut want = Vec::new();
    standalone.export_head_state(&layout, &mut want);
    assert!(!want.is_empty());

    // -- fleet under a tight budget ------------------------------------
    let (shared, state) = probe_plan(&ckpt);
    // budget fits the pool + exactly one spare state copy: with three
    // tenants every rotation forces park/unpark churn through the store
    let mut fleet = FleetService::build(
        conv_net(),
        OPT.0,
        OPT.1,
        frozen_spec(batch, epochs),
        DeviceProfile::unconstrained(),
        FleetConfig {
            checkpoint: Some(ckpt.clone()),
            park_store: StoreKind::File,
            quantum: 2,
            ..FleetConfig::new(shared + state, vec!["head".into()])
        },
    )
    .unwrap();
    assert_eq!(fleet.admission().max_resident, 2);

    let mut ids = Vec::new();
    for seed in [0xB0B_u64, matched_seed, 0xE7E] {
        let d = tenant_samples(seed ^ 0xDA7A, 16, in_len, lb_len);
        ids.push(fleet.admit(TenantSpec {
            seed,
            epochs,
            make_producer: Box::new(move || Box::new(CachedProducer::new(d.clone()))),
        }));
    }
    let matched = ids[1];
    let stats = fleet.run().unwrap();
    let _ = std::fs::remove_file(&ckpt);

    assert_eq!(stats.completed, 3);
    assert!(
        stats.parks > 3 && stats.unparks > 0,
        "budget was meant to force churn: {stats:?}"
    );
    assert_eq!(fleet.tenant_state(matched), TenantState::Finished);

    let got = fleet.tenant_head_state(matched).unwrap();
    assert_eq!(got.len(), want.len());
    for (k, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "state[{k}] diverged: fleet {g} vs standalone {w}"
        );
    }
}

// --------------------------------------------------------- admission math

#[test]
fn admission_plan_prices_tenants_marginally() {
    let (ckpt, ..) = vendor_checkpoint("plan");
    let (shared, state) = probe_plan(&ckpt);
    assert!(state > 0 && shared > state, "state should be a sliver of the pool");

    let budget = shared + 3 * state + state / 2;
    let fleet = FleetService::build(
        conv_net(),
        OPT.0,
        OPT.1,
        frozen_spec(4, 1),
        DeviceProfile::unconstrained(),
        FleetConfig {
            checkpoint: Some(ckpt.clone()),
            ..FleetConfig::new(budget, vec!["head".into()])
        },
    )
    .unwrap();
    let _ = std::fs::remove_file(&ckpt);
    let plan = fleet.admission();
    // 1 (active, inside the pool) + floor(remaining / state) buffers
    assert_eq!(plan.max_resident, 4);
    // the naive design pays the whole pool per user; the probe re-plans
    // the identical node set, so the two sides are directly comparable
    assert_eq!(plan.naive_session_bytes, plan.shared_pool_bytes);
    assert_eq!(plan.naive_total(100), 100 * plan.shared_pool_bytes);

    // too small to hold even one tenant: refused up front
    let err = FleetService::build(
        conv_net(),
        OPT.0,
        OPT.1,
        frozen_spec(4, 1),
        DeviceProfile::unconstrained(),
        FleetConfig::new(shared, vec!["head".into()]),
    )
    .unwrap_err();
    assert!(err.to_string().contains("too small"), "{err}");
}

// ------------------------------------------------------ isolation invariant

/// A trainable layer outside the head set would leak one tenant's
/// updates into every other tenant's model — the build must refuse it.
#[test]
fn build_rejects_trainable_layer_outside_head() {
    let err = FleetService::build(
        conv_net(),
        OPT.0,
        OPT.1,
        TrainSpec {
            batch: Some(4),
            // c1 left trainable but not in the head set
            freeze: vec!["c0".into()],
            ..Default::default()
        },
        DeviceProfile::unconstrained(),
        FleetConfig::new(usize::MAX / 2, vec!["head".into()]),
    )
    .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("c1") && msg.contains("head"), "{msg}");
}

// ----------------------------------------------------------- slot hygiene

#[test]
fn depart_releases_parked_store_slots() {
    let (ckpt, in_len, lb_len) = vendor_checkpoint("depart");
    let (shared, state) = probe_plan(&ckpt);
    let mut fleet = FleetService::build(
        conv_net(),
        OPT.0,
        OPT.1,
        frozen_spec(4, 1),
        DeviceProfile::unconstrained(),
        FleetConfig {
            checkpoint: Some(ckpt.clone()),
            quantum: 2,
            ..FleetConfig::new(shared + state, vec!["head".into()])
        },
    )
    .unwrap();
    let mut ids = Vec::new();
    for seed in [1u64, 2, 3, 4] {
        let d = tenant_samples(seed, 8, in_len, lb_len);
        ids.push(fleet.admit(TenantSpec {
            seed,
            epochs: 1,
            make_producer: Box::new(move || Box::new(CachedProducer::new(d.clone()))),
        }));
    }
    let stats = fleet.run().unwrap();
    let _ = std::fs::remove_file(&ckpt);
    assert_eq!(stats.completed, 4);
    // every finished tenant's final state holds one store slot
    assert_eq!(fleet.parked_slot_count(), 4);
    for id in ids {
        fleet.depart(id).unwrap();
        assert_eq!(fleet.tenant_state(id), TenantState::Departed);
    }
    assert_eq!(fleet.parked_slot_count(), 0, "departure must free store slots");
    assert_eq!(fleet.live_tenants(), 0);

    // a never-activated tenant has no state to fetch
    let fresh = fleet.admit(TenantSpec {
        seed: 9,
        epochs: 1,
        make_producer: Box::new(|| Box::new(CachedProducer::new(Vec::new()))),
    });
    assert!(fleet.tenant_head_state(fresh).is_err());
}

// ------------------------------------------------------ latency retention

/// The step-latency log is a ring: a long-lived service records only the
/// most recent `step_latency_cap` samples instead of growing without
/// bound, and shrinking the cap drops the oldest samples immediately.
#[test]
fn step_latency_log_is_ring_capped() {
    let (ckpt, in_len, lb_len) = vendor_checkpoint("latency");
    let mut fleet = FleetService::build(
        conv_net(),
        OPT.0,
        OPT.1,
        frozen_spec(4, 2),
        DeviceProfile::unconstrained(),
        FleetConfig {
            checkpoint: Some(ckpt.clone()),
            ..FleetConfig::new(usize::MAX / 2, vec!["head".into()])
        },
    )
    .unwrap();
    fleet.set_step_latency_cap(5);
    assert_eq!(fleet.step_latency_cap(), 5);
    for seed in [1u64, 2] {
        let d = tenant_samples(seed, 16, in_len, lb_len);
        fleet.admit(TenantSpec {
            seed,
            epochs: 2,
            make_producer: Box::new(move || Box::new(CachedProducer::new(d.clone()))),
        });
    }
    let stats = fleet.run().unwrap();
    let _ = std::fs::remove_file(&ckpt);
    // 2 tenants x 2 epochs x 4 batches — far more steps than the cap
    assert!(stats.steps > 5, "fixture should overflow the ring: {stats:?}");
    assert_eq!(
        fleet.step_latencies_ns().len(),
        5,
        "ring must retain exactly the cap"
    );
    assert!(fleet.step_latency_percentile(50.0) > 0);
    assert!(
        fleet.step_latency_percentile(99.0) >= fleet.step_latency_percentile(0.0)
    );
    // shrinking trims the oldest samples immediately
    let tail = fleet.step_latencies_ns()[3..].to_vec();
    fleet.set_step_latency_cap(2);
    assert_eq!(fleet.step_latencies_ns(), tail);
}
