//! Offload-advisor property tests over seeded random tensor tables, plus
//! gap-aware-planner validation on the same populations. The advisor is
//! pure analysis (Algorithm-1 EOs in, swap schedule out), so its
//! invariants can be hammered without running a model:
//!
//! * every entry's gap is genuinely idle (`evict_after < prefetch_before`,
//!   no use EO strictly inside, both endpoints are real use EOs)
//! * only idle-capable roles are offloaded, never weights/grads/opt state
//! * the advised peak never exceeds the unswapped peak, and never
//!   increases when the budget shrinks
//! * swap traffic is monotone: a smaller budget swaps at least as much
//! * `fits` is exactly `primary_peak_bytes <= budget`
//! * the gap-aware planner realizes every plan into a validated layout

use nntrainer::planner::offload::{advise, segments, OffloadPlan};
use nntrainer::planner::validate::{validate_gap_plan, validate_merges};
use nntrainer::planner::{GapFitPlanner, Planner};
use nntrainer::rng::Rng;
use nntrainer::tensor::{
    CreateMode, Initializer, Lifespan, TensorDim, TensorRole, TensorTable,
};

const EO_SPAN: u32 = 48;

/// A random table of Create-mode tensors with sorted, deduped EO sets —
/// the shape `init_graph` + `finish_orders` hands the planners.
fn random_table(rng: &mut Rng) -> TensorTable {
    let mut t = TensorTable::new();
    let n = 3 + rng.below(18);
    for i in 0..n {
        let role = match rng.below(6) {
            0 => TensorRole::Weight,
            1 => TensorRole::Gradient,
            2 => TensorRole::Temp,
            3 => TensorRole::Derivative,
            4 => TensorRole::OptState,
            _ => TensorRole::Activation,
        };
        let len = 1 + rng.below(512);
        let id = t
            .request(
                format!("t{i}"),
                TensorDim::vec(1, len),
                role,
                CreateMode::Create,
                Initializer::None,
            )
            .unwrap();
        if matches!(role, TensorRole::Weight | TensorRole::OptState) {
            t.add_eo(id, 0, Lifespan::MAX);
            t.add_eo(id, EO_SPAN, Lifespan::MAX);
        } else {
            let uses = 1 + rng.below(6);
            for _ in 0..uses {
                t.add_eo(id, rng.below(EO_SPAN as usize) as u32, Lifespan::FORWARD);
            }
        }
    }
    t.finish_orders();
    t
}

fn check_entries(t: &TensorTable, plan: &OffloadPlan) {
    let mut traffic = 0usize;
    for e in &plan.entries {
        let s = t.get(e.tensor);
        assert!(
            e.evict_after < e.prefetch_before,
            "`{}`: empty gap {} >= {}",
            e.name,
            e.evict_after,
            e.prefetch_before
        );
        assert!(
            !matches!(
                s.role,
                TensorRole::Weight
                    | TensorRole::Gradient
                    | TensorRole::OptState
                    | TensorRole::Input
            ),
            "`{}`: role {:?} must never be offloaded",
            e.name,
            s.role
        );
        assert!(!s.is_placeholder(), "`{}`: placeholders are externally bound", e.name);
        assert!(s.merged_into.is_none(), "`{}`: only roots get entries", e.name);
        // gap endpoints are real uses; the interior is genuinely idle
        assert!(s.eos.binary_search(&e.evict_after).is_ok());
        assert!(s.eos.binary_search(&e.prefetch_before).is_ok());
        for &eo in &s.eos {
            assert!(
                !(eo > e.evict_after && eo < e.prefetch_before),
                "`{}`: use EO {eo} inside gap ({}, {})",
                e.name,
                e.evict_after,
                e.prefetch_before
            );
        }
        assert_eq!(e.bytes, s.dim.bytes());
        traffic += 2 * e.bytes;
    }
    assert_eq!(traffic, plan.swap_bytes_per_iter, "traffic accounting drifted");
}

#[test]
fn advisor_invariants_random_tables() {
    let mut rng = Rng::new(20260731);
    for case in 0..200 {
        let t = random_table(&mut rng);
        let full = advise(&t, usize::MAX);
        assert!(full.entries.is_empty(), "case {case}: unconstrained budget swapped");
        assert!(full.fits);
        let unswapped_peak = full.primary_peak_bytes;

        // shrinking budgets: peak and traffic must be monotone
        let budgets = [
            unswapped_peak,
            unswapped_peak * 3 / 4,
            unswapped_peak / 2,
            unswapped_peak / 4,
            1,
        ];
        let mut prev_peak = usize::MAX;
        let mut prev_traffic = 0usize;
        for &budget in &budgets {
            let plan = advise(&t, budget);
            check_entries(&t, &plan);
            assert!(
                plan.primary_peak_bytes <= unswapped_peak,
                "case {case}: advised peak above unswapped peak"
            );
            assert!(
                plan.primary_peak_bytes <= prev_peak,
                "case {case}: peak grew as the budget shrank"
            );
            assert!(
                plan.swap_bytes_per_iter >= prev_traffic,
                "case {case}: traffic shrank as the budget shrank"
            );
            assert_eq!(
                plan.fits,
                plan.primary_peak_bytes <= budget,
                "case {case}: fits flag inconsistent with peak/budget"
            );
            prev_peak = plan.primary_peak_bytes;
            prev_traffic = plan.swap_bytes_per_iter;
        }
    }
}

#[test]
fn gapfit_realizes_every_plan() {
    let mut rng = Rng::new(777);
    for case in 0..100 {
        let mut t = random_table(&mut rng);
        let full_peak = advise(&t, usize::MAX).primary_peak_bytes;
        let budget = match case % 3 {
            0 => full_peak / 2,
            1 => full_peak / 4,
            _ => 1,
        };
        let plan = advise(&t, budget);
        let pool_len = GapFitPlanner { plan: &plan }.plan(&mut t).unwrap();
        validate_gap_plan(&t, &plan, pool_len).unwrap();
        validate_merges(&t).unwrap();
        // the realized pool can never beat the advised live-set bound
        assert!(
            pool_len * 4 >= plan.primary_peak_bytes,
            "case {case}: pool {} below the analytic bound {}",
            pool_len * 4,
            plan.primary_peak_bytes
        );
    }
}

#[test]
fn segments_and_gaps_agree() {
    let mut rng = Rng::new(9);
    for _ in 0..100 {
        let t = random_table(&mut rng);
        let plan = advise(&t, 1); // offload everything offloadable
        // per tensor: entries == consecutive-segment windows
        for s in t.iter() {
            let n_entries = plan.entries.iter().filter(|e| e.tensor == s.id).count();
            if n_entries > 0 {
                let segs = segments(&s.eos);
                assert_eq!(
                    n_entries,
                    segs.len() - 1,
                    "`{}`: one entry per idle gap",
                    s.name
                );
            }
        }
    }
}

/// Real-model sanity on top of the synthetic populations: the conv stack
/// from the advisor's unit tests, through graph init, at a 75% budget.
#[test]
fn real_model_plan_realizes() {
    use nntrainer::compiler::realizer::realize_all;
    use nntrainer::exec::{init_graph, InitOptions};
    use nntrainer::graph::{Graph, NodeDesc};
    use nntrainer::layers::{builtin_factories, Props};

    let nodes = vec![
        NodeDesc::new("in", "input", Props::from_pairs([("input_shape", "4:16:16")])),
        NodeDesc::new(
            "c0",
            "conv2d",
            Props::from_pairs([("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        ),
        NodeDesc::new(
            "c1",
            "conv2d",
            Props::from_pairs([("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        ),
        NodeDesc::new(
            "c2",
            "conv2d",
            Props::from_pairs([("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        ),
        NodeDesc::new("flat", "flatten", Props::new()),
        NodeDesc::new("fc", "fully_connected", Props::from_pairs([("unit", "10")])),
        NodeDesc::new("loss", "mse", Props::new()),
    ];
    let graph = Graph::wire(realize_all(nodes).unwrap()).unwrap();
    let mut ig = init_graph(
        &graph,
        &builtin_factories(),
        &InitOptions { batch: 32, ..Default::default() },
    )
    .unwrap();
    let full = advise(&ig.table, usize::MAX).primary_peak_bytes;
    let plan = advise(&ig.table, full * 75 / 100);
    assert!(plan.fits);
    check_entries(&ig.table, &plan);
    let pool_len = GapFitPlanner { plan: &plan }.plan(&mut ig.table).unwrap();
    validate_gap_plan(&ig.table, &plan, pool_len).unwrap();
    assert!(pool_len * 4 >= plan.primary_peak_bytes);
    assert!(pool_len * 4 < full, "gap-aware planning must beat the unswapped peak");
}
