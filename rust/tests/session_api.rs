//! Lifecycle-session API suite: the typestate path must be plan- and
//! training-equivalent to the seed `ModelBuilder::compile` shim, the
//! budget-aware auto-batch must be maximal and monotone in the budget,
//! freeze must shrink the planner table (not just skip updates),
//! `personalize` must leave frozen weights bitwise intact, callbacks must
//! observe and stop training, INI hyper-parameters must round-trip into a
//! trained model, and the best-fit gap placement must stay bitwise
//! swap-equivalent.

use nntrainer::compiler::{plan_only, CompileOpts};
use nntrainer::dataset::producer::{CachedProducer, Sample};
use nntrainer::dataset::{DataProducer, DigitsProducer};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{
    CallbackAction, CompiledSession, DeviceProfile, EarlyStop, ModelBuilder, OnIteration,
    Session, TrainSpec,
};
use nntrainer::planner::PlannerKind;
use nntrainer::rng::Rng;
use nntrainer::tensor::TensorRole;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

fn mlp() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:64")]),
        node("h0", "fully_connected", &[("unit", "48"), ("activation", "relu")]),
        node("h1", "fully_connected", &[("unit", "32"), ("activation", "relu")]),
        node("out", "fully_connected", &[("unit", "10")]),
        node("loss", "mse", &[]),
    ]
}

/// Conv backbone (`c0`, `c1`) + fc head (`head`) — the freeze /
/// personalize scenario.
fn conv_net() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "2:8:8")]),
        node("c0", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("head", "fully_connected", &[("unit", "6")]),
        node("loss", "mse", &[]),
    ]
}

fn feat_lens(cs: &CompiledSession) -> (usize, usize) {
    let exec = &cs.model.exec;
    let in_len = exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len = exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    (in_len, lb_len)
}

/// Fixed random dataset sized to the session's graph.
fn fixed_samples(cs: &CompiledSession, n: usize, seed: u64) -> Vec<Sample> {
    let (in_len, lb_len) = feat_lens(cs);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut input = vec![0f32; in_len];
            let mut label = vec![0f32; lb_len];
            rng.fill_uniform(&mut input, -1.0, 1.0);
            rng.fill_uniform(&mut label, 0.0, 1.0);
            Sample { input, label }
        })
        .collect()
}

fn probe_pool(nodes: Vec<NodeDesc>, batch: usize) -> usize {
    plan_only(nodes, &CompileOpts { batch, ..Default::default() }).unwrap().pool_bytes
}

// ------------------------------------------------------------- typestate

#[test]
fn typestate_matches_legacy_compile() {
    let batch = 8usize;
    let mut legacy = ModelBuilder::new()
        .add_nodes(mlp())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .compile(&CompileOpts { batch, ..Default::default() })
        .unwrap();
    let mut staged = Session::describe(mlp())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec { batch: Some(batch), ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    assert_eq!(legacy.peak_pool_bytes(), staged.peak_pool_bytes());
    assert_eq!(legacy.report.planner, staged.report().planner);

    let mut rng = Rng::new(0xBEEF);
    let mut input = vec![0f32; 64 * batch];
    let mut label = vec![0f32; 10 * batch];
    for it in 0..3 {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        legacy.bind_batch(&input, &label).unwrap();
        staged.model.bind_batch(&input, &label).unwrap();
        let l0 = legacy.exec.try_train_iteration().unwrap();
        let l1 = staged.model.exec.try_train_iteration().unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "iteration {it} diverged");
    }
}

// ------------------------------------------------------------- auto batch

#[test]
fn auto_batch_is_maximal_under_budget() {
    let budget = probe_pool(mlp(), 13);
    let cs = Session::describe(mlp())
        .optimizer("sgd", &[])
        .configure(TrainSpec { batch: None, ..Default::default() })
        .compile_for(DeviceProfile {
            memory_budget_bytes: Some(budget),
            swap: false,
            ..Default::default()
        })
        .unwrap();
    let b = cs.batch();
    assert!(b >= 13, "budget covers batch 13, got {b}");
    assert!(probe_pool(mlp(), b) <= budget, "selected batch overflows the budget");
    assert!(probe_pool(mlp(), b + 1) > budget, "batch {b} is not maximal");
    // the compiled plan is the probed plan
    assert_eq!(cs.report().pool_bytes, probe_pool(mlp(), b));
    assert_eq!(cs.fits_budget(), Some(true));
}

#[test]
fn auto_batch_monotone_in_budget() {
    let auto = |budget: usize| -> usize {
        Session::describe(mlp())
            .optimizer("sgd", &[])
            .configure(TrainSpec { batch: None, ..Default::default() })
            .compile_for(DeviceProfile {
                memory_budget_bytes: Some(budget),
                swap: false,
                ..Default::default()
            })
            .unwrap()
            .batch()
    };
    let budgets = [probe_pool(mlp(), 2), probe_pool(mlp(), 6), probe_pool(mlp(), 24)];
    let batches: Vec<usize> = budgets.iter().map(|&b| auto(b)).collect();
    assert!(batches[0] >= 2 && batches[1] >= 6 && batches[2] >= 24, "{batches:?}");
    assert!(
        batches[0] <= batches[1] && batches[1] <= batches[2],
        "batch not monotone in budget: {batches:?}"
    );
}

#[test]
fn auto_batch_swap_extends_the_feasible_batch() {
    // conv activations idle between forward and backward, so the swap
    // runtime's gap-aware pool fits more batch into the same budget
    let budget = probe_pool(conv_net(), 8);
    let auto = |swap: bool| -> CompiledSession {
        Session::describe(conv_net())
            .optimizer("sgd", &[])
            .configure(TrainSpec { batch: None, ..Default::default() })
            .compile_for(DeviceProfile {
                memory_budget_bytes: Some(budget),
                swap,
                ..Default::default()
            })
            .unwrap()
    };
    let plain = auto(false);
    let swapped = auto(true);
    assert!(plain.batch() >= 8);
    assert!(
        swapped.batch() >= plain.batch(),
        "swap shrank the feasible batch: {} < {}",
        swapped.batch(),
        plain.batch()
    );
    assert!(swapped.model.exec.swap_active());
    assert!(!plain.model.exec.swap_active());
}

#[test]
fn auto_batch_reaches_non_power_of_two_cap() {
    let auto = |budget: usize| -> usize {
        Session::describe(mlp())
            .optimizer("sgd", &[])
            .configure(TrainSpec { batch: None, ..Default::default() })
            .compile_for(DeviceProfile {
                memory_budget_bytes: Some(budget),
                swap: false,
                max_batch: 48,
                ..Default::default()
            })
            .unwrap()
            .batch()
    };
    // budget far above any pool: the answer is the cap itself, which the
    // power-of-two doubling alone would miss (…32, 64>cap)
    assert_eq!(auto(usize::MAX / 8), 48);
    // budget landing between the last power of two and the cap
    assert_eq!(auto(probe_pool(mlp(), 40)), 40);
}

#[test]
fn auto_batch_without_budget_uses_default() {
    let cs = Session::describe(mlp())
        .optimizer("sgd", &[])
        .configure(TrainSpec { batch: None, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    assert_eq!(cs.batch(), nntrainer::model::DEFAULT_BATCH);
}

// ----------------------------------------------------------------- freeze

fn role_count(cs: &CompiledSession, role: TensorRole) -> usize {
    cs.model
        .exec
        .graph
        .table
        .iter()
        .filter(|s| s.role == role && s.merged_into.is_none() && !s.eos.is_empty())
        .count()
}

#[test]
fn freeze_shrinks_planner_table() {
    let compile = |freeze: Vec<String>| -> CompiledSession {
        Session::describe(conv_net())
            .optimizer("adam", &[("learning_rate", "0.01")])
            .configure(TrainSpec { batch: Some(4), freeze, ..Default::default() })
            .compile_for(DeviceProfile::unconstrained())
            .unwrap()
    };
    let full = compile(vec![]);
    let frozen = compile(vec!["c0".into(), "c1".into()]);

    // no gradient or optimizer-state tensors planned for frozen layers
    assert!(
        role_count(&frozen, TensorRole::Gradient) < role_count(&full, TensorRole::Gradient),
        "gradient table did not shrink"
    );
    assert!(
        role_count(&frozen, TensorRole::OptState) < role_count(&full, TensorRole::OptState),
        "optimizer-state table did not shrink"
    );
    for s in frozen.model.exec.graph.table.iter() {
        let layer = s.name.split(':').next().unwrap();
        if layer == "c0" || layer == "c1" {
            assert!(
                !matches!(s.role, TensorRole::Gradient | TensorRole::OptState),
                "frozen layer planned `{}` ({:?})",
                s.name,
                s.role
            );
        }
    }
    // conv weight + bias per frozen conv layer
    assert_eq!(frozen.frozen_weight_names().len(), 4);
    assert!(full.frozen_weight_names().is_empty());
    assert!(
        frozen.peak_pool_bytes() <= full.peak_pool_bytes(),
        "freezing must not grow the pool"
    );
}

#[test]
fn freeze_unknown_prefix_errors() {
    let err = Session::describe(conv_net())
        .optimizer("sgd", &[])
        .configure(TrainSpec {
            batch: Some(2),
            freeze: vec!["nonexistent".into()],
            ..Default::default()
        })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap_err();
    assert!(err.to_string().contains("nonexistent"), "{err}");
}

// ------------------------------------------------------------ personalize

#[test]
fn personalize_keeps_frozen_weights_bitwise() {
    let data_seed = 0xDA7A;
    // vendor: train everything, checkpoint
    let mut vendor = Session::describe(conv_net())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec { batch: Some(4), epochs: 2, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let samples = fixed_samples(&vendor, 16, data_seed);
    let mk = samples.clone();
    let make = move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(mk.clone())) };
    vendor.train(&make).unwrap();
    let ckpt = std::env::temp_dir().join("session_api_personalize.nntr");
    let ckpt_path = ckpt.to_string_lossy().into_owned();
    vendor.save(&ckpt_path).unwrap();

    // user device: frozen backbone, fresh head, fine-tune
    let mut personal = Session::describe(conv_net())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec {
            batch: Some(4),
            epochs: 4,
            freeze: vec!["c0".into(), "c1".into()],
            ..Default::default()
        })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let frozen = personal.frozen_weight_names();
    assert_eq!(frozen.len(), 4);
    let report = personal
        .personalize(
            &nntrainer::model::PersonalizeOpts {
                checkpoint: Some(ckpt_path.clone()),
                reinit: vec!["head".into()],
                ..Default::default()
            },
            &make,
            &mut [],
        )
        .unwrap();
    let _ = std::fs::remove_file(&ckpt_path);

    assert!(report.restored > 0, "checkpoint restored nothing");
    assert_eq!(report.reinitialized, 2, "head weight + bias re-init");
    assert!(
        report.summary.final_loss < report.summary.losses_per_epoch[0],
        "fine-tune made no progress: {:?}",
        report.summary.losses_per_epoch
    );
    // frozen backbone bitwise identical to the vendor checkpoint
    for name in &frozen {
        let a = vendor.model.exec.read_weight(name).unwrap();
        let b = personal.model.exec.read_weight(name).unwrap();
        assert_eq!(a.len(), b.len());
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}[{k}]: {x} vs {y}");
        }
    }
    // the trainable head must actually have moved away from re-init
    let head_before = {
        // fresh compile, same seeds, reinit only — no training
        let mut probe = Session::describe(conv_net())
            .optimizer("sgd", &[("learning_rate", "0.05")])
            .configure(TrainSpec {
                batch: Some(4),
                freeze: vec!["c0".into(), "c1".into()],
                ..Default::default()
            })
            .compile_for(DeviceProfile::unconstrained())
            .unwrap();
        probe.model.exec.reinit_weights_matching(&["head".into()], 0x5EED).unwrap();
        probe.model.exec.read_weight("head:weight").unwrap()
    };
    let head_after = personal.model.exec.read_weight("head:weight").unwrap();
    assert_ne!(head_before, head_after, "head did not train");
}

#[test]
fn personalize_rejects_typoed_reinit_prefix() {
    let mut cs = Session::describe(conv_net())
        .optimizer("sgd", &[])
        .configure(TrainSpec { batch: Some(4), ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let before = cs.model.exec.read_weight("head:weight").unwrap();
    let err = cs
        .model
        .exec
        .reinit_weights_matching(&["haed".into()], 1)
        .unwrap_err();
    assert!(err.to_string().contains("haed"), "{err}");
    // fail-loud must also be fail-clean: nothing was mutated
    assert_eq!(before, cs.model.exec.read_weight("head:weight").unwrap());
}

// -------------------------------------------------------------- callbacks

#[test]
fn early_stop_ends_training() {
    let mut cs = Session::describe(mlp())
        .optimizer("sgd", &[("learning_rate", "0.0")]) // loss frozen → instant plateau
        .configure(TrainSpec { batch: Some(4), epochs: 30, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let samples = fixed_samples(&cs, 12, 7);
    let make = move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(samples.clone())) };
    let mut es = EarlyStop::new(2, 0.0);
    let summary = cs.train_with(&make, &mut [&mut es]).unwrap();
    // epoch 1 improves on +inf, epochs 2-3 plateau (lr 0) → stop at 3
    assert_eq!(summary.epochs, 3, "{:?}", summary.losses_per_epoch);
    assert_eq!(summary.losses_per_epoch.len(), 3);
    assert!(summary.iterations < 30 * 3);
}

#[test]
fn on_iteration_can_stop_mid_epoch() {
    let mut cs = Session::describe(mlp())
        .optimizer("sgd", &[("learning_rate", "0.01")])
        .configure(TrainSpec { batch: Some(4), epochs: 5, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let samples = fixed_samples(&cs, 40, 11); // 10 iterations per epoch
    let make = move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(samples.clone())) };
    let mut seen = 0usize;
    let mut stopper = OnIteration(|ev: &nntrainer::model::TrainEvent| {
        seen += 1;
        assert!(ev.loss.is_finite());
        if ev.iteration >= 3 {
            CallbackAction::Stop
        } else {
            CallbackAction::Continue
        }
    });
    let summary = cs.train_with(&make, &mut [&mut stopper]).unwrap();
    drop(stopper);
    assert_eq!(summary.iterations, 3, "stopped after the 3rd iteration");
    assert_eq!(summary.epochs, 1);
    assert_eq!(summary.losses_per_epoch.len(), 1, "partial epoch still reports a mean");
    assert_eq!(seen, 3);
}

// ------------------------------------------------------------------- INI

const ROUND_TRIP_INI: &str = r#"
[Model]
Type = NeuralNetwork
Loss = cross_entropy
Optimizer = sgd
Learning_rate = 0.4
Batch_Size = 4
Epochs = 3

[inputlayer]
Type = input
Input_Shape = 1:8:8

[fc0]
Type = fully_connected
Unit = 24
Activation = sigmoid

[fc1]
Type = fully_connected
Unit = 10
"#;

#[test]
fn ini_hyper_params_drive_the_session() {
    let session = Session::from_ini_str(ROUND_TRIP_INI).unwrap();
    let spec = session.default_spec();
    assert_eq!(spec.batch, Some(4));
    assert_eq!(spec.epochs, 3);

    let mut cs = session
        .configure_default()
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    assert_eq!(cs.batch(), 4);
    let make = || -> Box<dyn DataProducer> { Box::new(DigitsProducer::new(40, 8, 1, 3)) };
    let summary = cs.train(make).unwrap();
    assert_eq!(summary.epochs, 3, "INI Epochs drives the run");
    assert_eq!(summary.iterations, 30, "40 samples / batch 4 x 3 epochs");
    assert!(
        summary.final_loss < summary.losses_per_epoch[0],
        "INI learning rate produced no progress: {:?}",
        summary.losses_per_epoch
    );
}

// -------------------------------------------------- best-fit gap placement

#[test]
fn gap_bestfit_is_bitwise_swap_equivalent() {
    let batch = 8usize;
    let base_pool = probe_pool(conv_net(), batch);
    let budget = base_pool * 75 / 100;
    let mut base = Session::describe(conv_net())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec { batch: Some(batch), ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let mut bestfit = Session::describe(conv_net())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec { batch: Some(batch), ..Default::default() })
        .compile_for(DeviceProfile {
            memory_budget_bytes: Some(budget),
            planner: PlannerKind::BestFit,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(bestfit.report().planner, "gapfit-bestfit");
    assert!(bestfit.model.exec.swap_active());
    assert!(bestfit.peak_pool_bytes() < base_pool, "best-fit gap pool did not shrink");

    let (in_len, lb_len) = feat_lens(&base);
    let mut rng = Rng::new(0xFEED);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    for it in 0..4 {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        base.model.bind_batch(&input, &label).unwrap();
        bestfit.model.bind_batch(&input, &label).unwrap();
        let l0 = base.model.exec.try_train_iteration().unwrap();
        let l1 = bestfit.model.exec.try_train_iteration().unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "iteration {it}: best-fit placement diverged");
    }
}

// --------------------------------------------- validation split / memoize

/// Producer whose train and held-out batches disagree on purpose: with
/// `val_split = 0.5` the loop holds out every 2nd batch, so odd batch
/// indices (0-based) carry labels of the opposite sign. Training
/// memorizes `+0.8`; the held-out loss against `-0.8` can only grow.
struct SplitProducer {
    n: usize,
    in_len: usize,
    lb_len: usize,
    batch: usize,
}

impl DataProducer for SplitProducer {
    fn input_len(&self) -> usize {
        self.in_len
    }
    fn label_len(&self) -> usize {
        self.lb_len
    }
    fn len(&self) -> usize {
        self.n
    }
    fn sample(&mut self, idx: usize) -> Sample {
        let mut rng = Rng::new(1000 + idx as u64);
        let mut input = vec![0f32; self.in_len];
        rng.fill_uniform(&mut input, -1.0, 1.0);
        let sign = if (idx / self.batch) % 2 == 1 { -1.0f32 } else { 1.0f32 };
        Sample { input, label: vec![0.8 * sign; self.lb_len] }
    }
}

/// `TrainSpec::val_split`: EarlyStop must fire on the held-out loss
/// while the training loss is still falling.
#[test]
fn early_stop_fires_on_val_loss_while_train_falls() {
    let batch = 4usize;
    let mut cs = Session::describe(mlp())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec {
            batch: Some(batch),
            epochs: 10,
            val_split: 0.5,
            ..Default::default()
        })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let (in_len, lb_len) = feat_lens(&cs);
    let make = move || -> Box<dyn DataProducer> {
        Box::new(SplitProducer { n: 32, in_len, lb_len, batch })
    };
    let mut es = EarlyStop::new(1, 0.0);
    let summary = cs.train_with(&make, &mut [&mut es]).unwrap();
    assert!(
        summary.epochs < 10,
        "early stop never fired on the held-out loss: {:?}",
        summary.val_losses_per_epoch
    );
    assert_eq!(
        summary.val_losses_per_epoch.len(),
        summary.epochs,
        "one held-out mean per epoch"
    );
    let tl = &summary.losses_per_epoch;
    assert!(
        tl.last().unwrap() < tl.first().unwrap(),
        "training loss was not still falling: {tl:?}"
    );
    let vl = &summary.val_losses_per_epoch;
    assert!(
        vl.last().unwrap() >= vl.first().unwrap(),
        "held-out loss should plateau or grow on disagreeing labels: {vl:?}"
    );
    // half the batches were held out: they are not training iterations
    assert_eq!(summary.iterations, summary.epochs * 4, "4 train batches per epoch");
}

/// The same guarantee on the `personalize()` path: a fine-tune with
/// `val_split` must stop on a rising held-out loss, not train to the
/// epoch cap — the fine-tuned head is exactly where overfit bites.
#[test]
fn early_stop_fires_on_val_loss_during_personalize() {
    let batch = 4usize;
    // vendor: full train, checkpoint
    let mut vendor = Session::describe(conv_net())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec { batch: Some(batch), epochs: 2, ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let samples = fixed_samples(&vendor, 16, 0x0DD);
    let vmake =
        move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(samples.clone())) };
    vendor.train(&vmake).unwrap();
    let ckpt = std::env::temp_dir()
        .join(format!("session_api_es_personalize_{}.nntr", std::process::id()))
        .to_string_lossy()
        .into_owned();
    vendor.save(&ckpt).unwrap();

    // user device: frozen backbone, held-out split, disagreeing labels
    let mut personal = Session::describe(conv_net())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec {
            batch: Some(batch),
            epochs: 10,
            val_split: 0.5,
            freeze: vec!["c0".into(), "c1".into()],
            ..Default::default()
        })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let (in_len, lb_len) = feat_lens(&personal);
    let make = move || -> Box<dyn DataProducer> {
        Box::new(SplitProducer { n: 32, in_len, lb_len, batch })
    };
    let mut es = EarlyStop::new(1, 0.0);
    let report = personal
        .personalize(
            &nntrainer::model::PersonalizeOpts {
                checkpoint: Some(ckpt.clone()),
                reinit: vec!["head".into()],
                ..Default::default()
            },
            &make,
            &mut [&mut es],
        )
        .unwrap();
    let _ = std::fs::remove_file(&ckpt);

    assert!(report.restored > 0);
    assert_eq!(report.reinitialized, 2);
    let summary = &report.summary;
    assert!(
        summary.epochs < 10,
        "early stop never fired during personalize: {:?}",
        summary.val_losses_per_epoch
    );
    assert_eq!(summary.val_losses_per_epoch.len(), summary.epochs);
    let vl = &summary.val_losses_per_epoch;
    assert!(
        vl.last().unwrap() >= vl.first().unwrap(),
        "held-out loss should plateau or grow on disagreeing labels: {vl:?}"
    );
    // half held out -> 4 training iterations per epoch
    assert_eq!(summary.iterations, summary.epochs * 4);
}

/// Auto-batch memoization: the whole budget search costs two reference
/// shape analyses (the template) plus the final compile — probe count
/// does not move the per-layer analysis counter, and the selected batch
/// equals what per-probe full analysis selects.
#[test]
fn auto_batch_memoizes_shape_analysis() {
    use nntrainer::compiler::plan_with;
    use nntrainer::exec::shape_analysis_count;
    use nntrainer::layers::builtin_factories;

    // one full compile = one pass of per-layer analysis (the unit)
    let before = shape_analysis_count();
    let _fixed = Session::describe(mlp())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec { batch: Some(4), ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap();
    let per_compile = shape_analysis_count() - before;
    assert!(per_compile > 0);

    let budget = probe_pool(mlp(), 12);
    let max_batch = 32usize;

    let before = shape_analysis_count();
    let cs = Session::describe(mlp())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .configure(TrainSpec { batch: None, ..Default::default() })
        .compile_for(DeviceProfile {
            memory_budget_bytes: Some(budget),
            max_batch,
            ..Default::default()
        })
        .unwrap();
    let probe_analyses = shape_analysis_count() - before;
    assert_eq!(
        probe_analyses,
        3 * per_compile,
        "auto-batch must analyze shapes exactly 3x (2 template refs + final \
         compile), independent of probe count"
    );

    // the memoized search selects the same batch as per-probe full
    // analysis: largest b <= max_batch whose planned (budgeted) pool fits
    let factories = builtin_factories();
    let mut expected = 1usize;
    for b in 1..=max_batch {
        let rep = plan_with(
            mlp(),
            &CompileOpts {
                batch: b,
                memory_budget_bytes: Some(budget),
                ..Default::default()
            },
            &factories,
            0,
        )
        .unwrap();
        if rep.pool_bytes <= budget {
            expected = b;
        }
    }
    assert_eq!(cs.batch(), expected, "memoization changed the selected batch");
}

// ------------------------------------------- compute-backend equivalence

/// Small recurrent stack — routes every lstm/gru GEMM (including the
/// accumulate-into-nonzero per-timestep chains) through the backend.
fn recurrent_net() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:5:8")]),
        node("l0", "lstm", &[("unit", "6"), ("return_sequences", "true")]),
        node("g0", "gru", &[("unit", "4")]),
        node("loss", "mse", &[]),
    ]
}

/// Train the same description under two device profiles differing ONLY
/// in `compute`; per-iteration losses and final weights must be bitwise
/// equal. The tiered backend partitions disjoint output elements across
/// the worker pool and never reassociates an accumulation chain, so
/// this holds exactly — `to_bits()`, not a tolerance (DESIGN.md
/// §Compute backend).
fn assert_compute_equivalence(nodes: fn() -> Vec<NodeDesc>, batch: usize, iters: usize) {
    let build = |profile: DeviceProfile| {
        Session::describe(nodes())
            .optimizer("sgd", &[("learning_rate", "0.05")])
            .configure(TrainSpec { batch: Some(batch), ..Default::default() })
            .compile_for(profile)
            .unwrap()
    };
    let mut naive = build(DeviceProfile::unconstrained().naive_compute());
    let mut tiered = build(DeviceProfile::unconstrained());

    let (in_len, lb_len) = feat_lens(&naive);
    let mut rng = Rng::new(0x71E2ED);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    for it in 0..iters {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        naive.model.bind_batch(&input, &label).unwrap();
        tiered.model.bind_batch(&input, &label).unwrap();
        let l0 = naive.model.exec.try_train_iteration().unwrap();
        let l1 = tiered.model.exec.try_train_iteration().unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "iteration {it}: loss diverged ({l0} vs {l1})");
    }
    for w in naive.model.exec.weight_names() {
        let a = naive.model.exec.read_weight(&w).unwrap();
        let b = tiered.model.exec.read_weight(&w).unwrap();
        assert_eq!(a.len(), b.len(), "{w}: length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{w}[{i}]: {x} vs {y} after {iters} iterations");
        }
    }
}

#[test]
fn naive_and_tiered_training_bitwise_equal_conv() {
    assert_compute_equivalence(conv_net, 4, 4);
}

#[test]
fn naive_and_tiered_training_bitwise_equal_recurrent() {
    assert_compute_equivalence(recurrent_net, 4, 4);
}

/// Dropping conv's materialized im2col temp must show up in the planned
/// pool: the tiered compile of a conv net plans a strictly smaller peak
/// than the naive compile of the same description at the same batch.
#[test]
fn tiered_conv_plans_smaller_pool_than_naive() {
    let build = |profile: DeviceProfile| {
        Session::describe(conv_net())
            .optimizer("sgd", &[("learning_rate", "0.05")])
            .configure(TrainSpec { batch: Some(8), ..Default::default() })
            .compile_for(profile)
            .unwrap()
    };
    let naive = build(DeviceProfile::unconstrained().naive_compute());
    let tiered = build(DeviceProfile::unconstrained());
    assert!(
        tiered.peak_pool_bytes() < naive.peak_pool_bytes(),
        "implicit-GEMM conv did not shrink the planned peak: tiered {} vs naive {}",
        tiered.peak_pool_bytes(),
        naive.peak_pool_bytes()
    );
}
