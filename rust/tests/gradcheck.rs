//! Finite-difference gradient checks through the *entire* stack (graph →
//! realizers → Algorithm 1 → planner → executor), covering every layer
//! type. This is the strongest correctness signal the engine has: a
//! planner that aliases two live tensors, a wrong EO, or a bad backward
//! formula all surface here.

use nntrainer::compiler::CompileOpts;
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{ModelBuilder, TrainConfig};
use nntrainer::planner::PlannerKind;
use nntrainer::rng::Rng;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

/// Build, bind a deterministic batch, and finite-difference-check sampled
/// weight entries of every trainable tensor.
fn gradcheck(nodes: Vec<NodeDesc>, batch: usize, in_len: usize, label_len: usize, tol: f32) {
    gradcheck_abs(nodes, batch, in_len, label_len, tol, 5e-3)
}

/// `abs_tol` loosens the check for models with max-pool / relu kinks,
/// where finite differences near argmax ties are legitimately inaccurate
/// (the analytic gradient is the subgradient; verified deterministic).
fn gradcheck_abs(
    nodes: Vec<NodeDesc>,
    batch: usize,
    in_len: usize,
    label_len: usize,
    tol: f32,
    abs_tol: f32,
) {
    let opts = CompileOpts {
        batch,
        // huge clip norm → deferred apply → grads survive the iteration
        clip_norm: Some(1e12),
        planner: PlannerKind::Sorting,
        ..Default::default()
    };
    let mut model = ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", "0.0")])
        .compile(&opts)
        .unwrap();

    let mut rng = Rng::new(99);
    let mut input = vec![0f32; batch * in_len];
    let mut label = vec![0f32; batch * label_len];
    rng.fill_uniform(&mut input, -1.0, 1.0);
    rng.fill_uniform(&mut label, 0.0, 1.0);

    let weight_names = model.exec.weight_names();
    assert!(!weight_names.is_empty());
    let mut checked = 0usize;
    for wname in &weight_names {
        // fresh baseline iteration so the gradient buffers reflect the
        // *unperturbed* weights (previous FD probes left stale grads)
        model.bind_batch(&input, &label).unwrap();
        model.exec.train_iteration();
        let gname = format!("{wname}:grad");
        let Ok(grad) = model.exec.read_weight(&gname) else {
            continue; // frozen weight
        };
        let w0 = model.exec.read_weight(wname).unwrap();
        // sample a few indices per weight
        let mut idxs: Vec<usize> = (0..w0.len().min(4)).collect();
        if w0.len() > 8 {
            idxs.push(w0.len() / 2);
            idxs.push(w0.len() - 1);
        }
        for &i in &idxs {
            let eps = 5e-3f32.max(w0[i].abs() * 1e-2);
            let mut wp = w0.clone();
            wp[i] += eps;
            model.exec.write_weight(wname, &wp).unwrap();
            model.bind_batch(&input, &label).unwrap();
            let lp = model.exec.train_iteration();
            wp[i] = w0[i] - eps;
            model.exec.write_weight(wname, &wp).unwrap();
            model.bind_batch(&input, &label).unwrap();
            let lm = model.exec.train_iteration();
            model.exec.write_weight(wname, &w0).unwrap();
            let numeric = (lp - lm) / (2.0 * eps);
            let analytic = grad[i];
            let denom = numeric.abs().max(analytic.abs()).max(1e-3);
            let rel = (numeric - analytic).abs() / denom;
            assert!(
                rel < tol || (numeric - analytic).abs() < abs_tol,
                "{wname}[{i}]: numeric {numeric:.6} vs analytic {analytic:.6} (rel {rel:.4})"
            );
            checked += 1;
        }
    }
    assert!(checked > 0, "no gradients checked");
}

#[test]
fn gradcheck_fc_sigmoid_mse() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:1:6")]),
            node("fc0", "fully_connected", &[("unit", "5"), ("activation", "sigmoid")]),
            node("fc1", "fully_connected", &[("unit", "3")]),
            node("loss", "mse", &[]),
        ],
        4,
        6,
        3,
        2e-2,
    );
}

#[test]
fn gradcheck_fc_tanh_relu_softmax_xent() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:1:6")]),
            node("fc0", "fully_connected", &[("unit", "8"), ("activation", "tanh")]),
            node("fc1", "fully_connected", &[("unit", "8"), ("activation", "relu")]),
            node("fc2", "fully_connected", &[("unit", "4")]),
            node("loss", "cross_entropy", &[]),
        ],
        3,
        6,
        4,
        3e-2,
    );
}

#[test]
fn gradcheck_conv_pool_flatten() {
    gradcheck_abs(
        vec![
            node("in", "input", &[("input_shape", "2:8:8")]),
            node(
                "c0",
                "conv2d",
                &[("filters", "3"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")],
            ),
            node("p0", "pooling2d", &[("pooling", "max"), ("pool_size", "2")]),
            node("c1", "conv2d", &[("filters", "2"), ("kernel_size", "3"), ("stride", "1")]),
            node("flat", "flatten", &[]),
            node("fc", "fully_connected", &[("unit", "3")]),
            node("loss", "mse", &[]),
        ],
        2,
        2 * 8 * 8,
        3,
        3e-2,
        2e-2, // max-pool kinks (see gradcheck_abs docs)
    );
}

#[test]
fn gradcheck_avgpool_conv_stride() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:9:9")]),
            node("c0", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("stride", "2"), ("padding", "1")]),
            node("p0", "pooling2d", &[("pooling", "average"), ("pool_size", "2")]),
            node("flat", "flatten", &[]),
            node("fc", "fully_connected", &[("unit", "2")]),
            node("loss", "mse", &[]),
        ],
        2,
        81,
        2,
        3e-2,
    );
}

#[test]
fn gradcheck_lstm_sequence() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:5:4")]), // T=5, feat=4
            node("lstm0", "lstm", &[("unit", "6"), ("return_sequences", "true")]),
            node("lstm1", "lstm", &[("unit", "3")]),
            node("loss", "mse", &[]),
        ],
        2,
        20,
        3,
        3e-2,
    );
}

#[test]
fn gradcheck_batchnorm() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "2:4:4")]),
            node("c0", "conv2d", &[("filters", "3"), ("kernel_size", "3"), ("padding", "same")]),
            node("bn", "batch_normalization", &[]),
            node("act", "activation", &[("act", "relu")]),
            node("flat", "flatten", &[]),
            node("fc", "fully_connected", &[("unit", "2")]),
            node("loss", "mse", &[]),
        ],
        4,
        32,
        2,
        5e-2,
    );
}

#[test]
fn gradcheck_multiout_addition_concat() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:1:5")]),
            node("fc0", "fully_connected", &[("unit", "6")]),
            // two consumers of fc0 → multiout realizer kicks in
            node("a", "fully_connected", &[("unit", "6"), ("activation", "sigmoid"), ("input_layers", "fc0")]),
            node("b", "fully_connected", &[("unit", "6"), ("activation", "tanh"), ("input_layers", "fc0")]),
            node("add", "addition", &[("input_layers", "a,b")]),
            node("cat", "concat", &[("input_layers", "add,fc0")]),
            node("fc1", "fully_connected", &[("unit", "2")]),
            node("loss", "mse", &[]),
        ],
        3,
        5,
        2,
        3e-2,
    );
}

#[test]
fn gradcheck_embedding() {
    // indices must be valid ids → craft input manually through a custom
    // producer-style batch
    let opts = CompileOpts {
        batch: 4,
        clip_norm: Some(1e12),
        ..Default::default()
    };
    let mut model = ModelBuilder::new()
        .add_nodes(vec![
            node("in", "input", &[("input_shape", "1:1:2")]),
            node("emb", "embedding", &[("in_dim", "10"), ("out_dim", "4")]),
            node("flat", "flatten", &[]),
            node("fc", "fully_connected", &[("unit", "2")]),
            node("loss", "mse", &[]),
        ])
        .optimizer("sgd", &[("learning_rate", "0.0")])
        .compile(&opts)
        .unwrap();
    let input = vec![0.0, 3.0, 7.0, 2.0, 9.0, 9.0, 1.0, 5.0];
    let label = vec![0.5, -0.5, 0.2, 0.1, 0.9, -0.1, 0.0, 0.3];
    model.bind_batch(&input, &label).unwrap();
    model.exec.train_iteration();
    let grad = model.exec.read_weight("emb:table:grad").unwrap();
    let w0 = model.exec.read_weight("emb:table").unwrap();
    // row 3 was used; check one entry numerically
    let i = 3 * 4;
    let eps = 1e-2;
    let mut wp = w0.clone();
    wp[i] += eps;
    model.exec.write_weight("emb:table", &wp).unwrap();
    model.bind_batch(&input, &label).unwrap();
    let lp = model.exec.train_iteration();
    wp[i] = w0[i] - eps;
    model.exec.write_weight("emb:table", &wp).unwrap();
    model.bind_batch(&input, &label).unwrap();
    let lm = model.exec.train_iteration();
    let numeric = (lp - lm) / (2.0 * eps);
    let rel = (numeric - grad[i]).abs() / numeric.abs().max(grad[i].abs()).max(1e-3);
    assert!(rel < 3e-2, "numeric {numeric} vs {}", grad[i]);
}

#[test]
fn gradcheck_attention() {
    gradcheck(
        vec![
            node("q_in", "input", &[("input_shape", "1:1:4")]),
            node("m_in", "input", &[("input_shape", "1:6:4")]), // T=6, H=4
            node("q", "fully_connected", &[("unit", "4"), ("input_layers", "q_in")]),
            node("att", "attention", &[("input_layers", "q,m_in")]),
            node("fc", "fully_connected", &[("unit", "2")]),
            node("loss", "mse", &[]),
        ],
        2,
        4 + 24,
        2,
        3e-2,
    );
}

#[test]
fn gradcheck_dropout_inference_path_excluded() {
    // dropout at rate 0 must be exactly identity in backward
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:1:6")]),
            node("fc0", "fully_connected", &[("unit", "5")]),
            node("do", "dropout", &[("rate", "0.0")]),
            node("fc1", "fully_connected", &[("unit", "2")]),
            node("loss", "mse", &[]),
        ],
        2,
        6,
        2,
        2e-2,
    );
}

#[test]
fn gradcheck_conv1d() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "3:1:12")]), // C=3, T=12
            node("c0", "conv1d", &[("filters", "4"), ("kernel_size", "5"), ("padding", "same"), ("activation", "tanh")]),
            node("c1", "conv1d", &[("filters", "2"), ("kernel_size", "3"), ("padding", "same")]),
            node("flat", "flatten", &[]),
            node("fc", "fully_connected", &[("unit", "2")]),
            node("loss", "mse", &[]),
        ],
        2,
        36,
        2,
        3e-2,
    );
}

#[test]
fn gradcheck_time_distributed_fc() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:4:3")]), // T=4, F=3
            node("td0", "fully_connected", &[("unit", "5"), ("time_distributed", "true"), ("activation", "relu")]),
            node("lstm", "lstm", &[("unit", "3")]),
            node("loss", "mse", &[]),
        ],
        2,
        12,
        3,
        3e-2,
    );
}

/// Sanity: a small model actually learns (loss decreases monotonically-ish).
#[test]
fn training_reduces_loss() {
    use nntrainer::dataset::{DataProducer, RandomProducer};
    let opts = CompileOpts { batch: 8, ..Default::default() };
    let mut model = ModelBuilder::new()
        .add_nodes(vec![
            node("in", "input", &[("input_shape", "1:1:8")]),
            node("fc0", "fully_connected", &[("unit", "16"), ("activation", "sigmoid")]),
            node("fc1", "fully_connected", &[("unit", "4")]),
            node("loss", "cross_entropy", &[]),
        ])
        .optimizer("sgd", &[("learning_rate", "0.5")])
        .compile(&opts)
        .unwrap();
    let make = || -> Box<dyn DataProducer> { Box::new(RandomProducer::new(64, 8, 4, 7)) };
    let summary = model
        .train(make, &TrainConfig { epochs: 60, ..Default::default() })
        .unwrap();
    let first = summary.losses_per_epoch[0];
    let last = summary.final_loss;
    // random labels are memorizable with 64 fixed samples; expect a clear drop
    assert!(last < first * 0.9, "loss did not decrease: {first} -> {last}");
}

#[test]
fn gradcheck_gru_sequence() {
    gradcheck(
        vec![
            node("in", "input", &[("input_shape", "1:5:4")]), // T=5, feat=4
            node("gru0", "gru", &[("unit", "6"), ("return_sequences", "true")]),
            node("gru1", "gru", &[("unit", "3")]),
            node("loss", "mse", &[]),
        ],
        2,
        20,
        3,
        3e-2,
    );
}
