//! Randomized-topology swap-equivalence stress suite: a seeded
//! generator over model shapes (fc / conv / concat / multiout mixes),
//! budgets, stores and tunings, asserting for every sample that
//!
//! * training under the budget through the full-duplex swap runtime is
//!   **bitwise identical** to unswapped training (losses every
//!   iteration, all weights at the end),
//! * the realized layout still validates against the offload plan
//!   (`validate_gap_plan` over the planned table and the allocated
//!   pool), and
//! * swap traffic is symmetric and matches the plan's accounting.
//!
//! Every assertion message carries the reproducing `seed=… sample=…`
//! context so a CI failure pins the exact topology. The seed matrix and
//! store set are environment-tunable for the CI stress job:
//!
//! * `NNTRAINER_STRESS_SEEDS`    — comma-separated u64 seeds
//!   (default `20260731`)
//! * `NNTRAINER_STRESS_STORE`    — `host`, `file`, `file-compressed`,
//!   `both` (host+file, the default) or `all` (adds the compressed
//!   store)
//! * `NNTRAINER_STRESS_SAMPLES`  — topologies per seed (default 6)
//! * `NNTRAINER_STRESS_PIPELINE` — `on`, `off` or `mixed` (default):
//!   whether samples compile with cross-iteration swap pipelining
//!   (`swap_pipeline`, wrap entries carried across `end_iteration`);
//!   `mixed` alternates it across samples so one run covers both

use nntrainer::compiler::CompileOpts;
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{Model, ModelBuilder};
use nntrainer::planner::offload::advise;
use nntrainer::planner::validate::validate_gap_plan;
use nntrainer::rng::Rng;
use nntrainer::runtime::{StoreKind, SwapTuning};

fn node(name: &str, ltype: &str, pairs: &[(&str, String)]) -> NodeDesc {
    NodeDesc::new(
        name,
        ltype,
        Props::from_pairs(pairs.iter().map(|(k, v)| (*k, v.as_str()))),
    )
}

/// One random topology out of the four families the paper's evaluation
/// models span: plain fc stacks, conv stacks, a multiout→concat fork
/// and a multiout→addition fork (model-D shape).
fn gen_model(rng: &mut Rng) -> Vec<NodeDesc> {
    match rng.below(4) {
        0 => {
            // fc stack
            let feat = 32 + rng.below(128);
            let depth = 2 + rng.below(3);
            let mut nodes = vec![node(
                "in",
                "input",
                &[("input_shape", format!("1:1:{feat}"))],
            )];
            for i in 0..depth {
                let unit = 16 + rng.below(80);
                nodes.push(node(
                    &format!("h{i}"),
                    "fully_connected",
                    &[("unit", unit.to_string()), ("activation", "relu".into())],
                ));
            }
            nodes.push(node("out", "fully_connected", &[("unit", "8".into())]));
            nodes.push(node("loss", "mse", &[]));
            nodes
        }
        1 => {
            // conv stack
            let c = 1 + rng.below(4);
            let hw = [8, 12, 16][rng.below(3)];
            let depth = 1 + rng.below(3);
            let mut nodes = vec![node(
                "in",
                "input",
                &[("input_shape", format!("{c}:{hw}:{hw}"))],
            )];
            for i in 0..depth {
                let filters = 4 + rng.below(12);
                nodes.push(node(
                    &format!("c{i}"),
                    "conv2d",
                    &[
                        ("filters", filters.to_string()),
                        ("kernel_size", "3".into()),
                        ("padding", "same".into()),
                        ("activation", "relu".into()),
                    ],
                ));
            }
            nodes.push(node("flat", "flatten", &[]));
            nodes.push(node("fc", "fully_connected", &[("unit", "10".into())]));
            nodes.push(node("loss", "mse", &[]));
            nodes
        }
        2 => {
            // multiout fork joined by concat
            let feat = 32 + rng.below(96);
            let ua = 16 + rng.below(48);
            let ub = 16 + rng.below(48);
            vec![
                node("in", "input", &[("input_shape", format!("1:1:{feat}"))]),
                node("stem", "fully_connected", &[("unit", "48".into()), ("activation", "relu".into())]),
                node("mo", "multiout", &[("outputs", "2".into())]),
                node("ba", "fully_connected", &[("unit", ua.to_string()), ("activation", "relu".into()), ("input_layers", "mo(0)".into())]),
                node("bb", "fully_connected", &[("unit", ub.to_string()), ("activation", "relu".into()), ("input_layers", "mo(1)".into())]),
                node("cat", "concat", &[("input_layers", "ba,bb".into())]),
                node("head", "fully_connected", &[("unit", "8".into())]),
                node("loss", "mse", &[]),
            ]
        }
        _ => {
            // multiout fork joined by addition (model-D shape)
            let feat = 64 + rng.below(128);
            let unit = 24 + rng.below(64);
            vec![
                node("in", "input", &[("input_shape", format!("1:1:{feat}"))]),
                node("stem", "fully_connected", &[("unit", unit.to_string()), ("bias", "false".into())]),
                node("mo", "multiout", &[("outputs", "2".into())]),
                node("act_a", "activation", &[("act", "sigmoid".into()), ("input_layers", "mo(0)".into())]),
                node("act_b", "activation", &[("act", "relu".into()), ("input_layers", "mo(1)".into())]),
                node("add", "addition", &[("input_layers", "act_a,act_b".into())]),
                node("head", "fully_connected", &[("unit", "10".into()), ("bias", "false".into())]),
                node("loss", "mse", &[]),
            ]
        }
    }
}

fn compile(nodes: Vec<NodeDesc>, opts: &CompileOpts) -> Model {
    ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .compile(opts)
        .unwrap()
}

fn feat_lens(m: &Model) -> (usize, usize) {
    let in_len = m
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len = m
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    (in_len, lb_len)
}

/// One stress sample: generate a topology, train it unswapped and under
/// a random tight budget with identical data, and hold the bitwise +
/// plan-validity contract.
fn run_sample(seed: u64, sample: usize, store: StoreKind, tuning: SwapTuning, pipeline: bool) {
    let ctx =
        format!("seed={seed} sample={sample} store={store:?} tuning={tuning:?} pipeline={pipeline}");
    let mut rng = Rng::new(seed ^ (sample as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let nodes = gen_model(&mut rng);
    let batch = [4usize, 8][rng.below(2)];
    let budget_pct = 60 + rng.below(31); // 60..=90 %
    let iters = 3; // past the calibrated warmup, into observed feedback

    let base_opts = CompileOpts { batch, ..Default::default() };
    let mut base = compile(nodes.clone(), &base_opts);
    let full = advise(&base.exec.graph.table, usize::MAX).primary_peak_bytes;
    let budget = (full * budget_pct / 100).max(1);

    let mut swapped = compile(
        nodes,
        &CompileOpts {
            batch,
            memory_budget_bytes: Some(budget),
            swap_store: store,
            swap_tuning: tuning,
            swap_pipeline: pipeline,
            ..Default::default()
        },
    );
    assert!(swapped.exec.swap_active(), "{ctx}: swap runtime not engaged");
    let plan = swapped.exec.swap_plan().unwrap().clone();

    // plan validity against the realized layout (the allocated pool)
    let pool_len = swapped.exec.pool.len();
    validate_gap_plan(&swapped.exec.graph.table, &plan, pool_len)
        .unwrap_or_else(|e| panic!("{ctx}: realized plan invalid: {e}"));

    let (in_len, lb_len) = feat_lens(&base);
    let mut data_rng = Rng::new(0xC0FFEE ^ seed);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    for it in 0..iters {
        data_rng.fill_uniform(&mut input, -1.0, 1.0);
        data_rng.fill_uniform(&mut label, 0.0, 1.0);
        base.bind_batch(&input, &label).unwrap();
        swapped.bind_batch(&input, &label).unwrap();
        let l0 = base.exec.try_train_iteration().unwrap();
        let l1 = swapped
            .exec
            .try_train_iteration()
            .unwrap_or_else(|e| panic!("{ctx}: swapped iteration {it} failed: {e}"));
        assert_eq!(
            l0.to_bits(),
            l1.to_bits(),
            "{ctx}: iteration {it} loss diverged ({l0} vs {l1})"
        );
    }

    // run end is a mandatory full-drain point: under pipelining the
    // engine may still carry boundary transfers over weight regions
    if pipeline {
        swapped
            .exec
            .quiesce_swap()
            .unwrap_or_else(|e| panic!("{ctx}: quiesce failed: {e}"));
    }

    for w in base.exec.weight_names() {
        let a = base.exec.read_weight(&w).unwrap();
        let b = swapped.exec.read_weight(&w).unwrap();
        assert_eq!(a.len(), b.len(), "{ctx}: {w}: length");
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: {w}[{k}]: {x} vs {y} after {iters} iterations"
            );
        }
    }

    // traffic accounting — only when the budget actually forced offloads
    let stats = swapped.exec.swap_stats().unwrap();
    if plan.entries.is_empty() {
        assert_eq!(stats.bytes_out, 0, "{ctx}: traffic without entries");
    } else {
        assert!(stats.bytes_out > 0, "{ctx}: no eviction traffic: {stats:?}");
        assert_eq!(
            stats.bytes_out, stats.bytes_in,
            "{ctx}: swap traffic asymmetric: {stats:?}"
        );
        // Each wrap entry pays one extra one-way trip on top of the
        // per-iteration cycle: the first `begin_iteration` primes it out
        // (eviction), and `quiesce_swap` restores the carried copy after
        // the last iteration (prefetch). Non-pipelined plans have no
        // wrap entries, so this reduces to the old exact formula.
        let wrap_oneway: u64 = plan
            .entries
            .iter()
            .filter(|e| e.wrap)
            .map(|e| e.bytes as u64)
            .sum();
        assert_eq!(
            stats.bytes_out,
            iters as u64 * (plan.swap_bytes_per_iter / 2) as u64 + wrap_oneway,
            "{ctx}: traffic does not match the advised per-iteration swap bytes"
        );
    }
}

// The overrides fail loudly on anything unparseable: a typo'd CI matrix
// cell silently falling back to the defaults would *look* like coverage
// (green job, wrong seeds) — the same swallow-and-default bug class the
// bench harness had in `bench_dataset`.

fn env_seeds() -> Vec<u64> {
    match std::env::var("NNTRAINER_STRESS_SEEDS") {
        Ok(s) => {
            let seeds: Vec<u64> = s
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|e| {
                        panic!("NNTRAINER_STRESS_SEEDS part {p:?} is not a u64: {e}")
                    })
                })
                .collect();
            if seeds.is_empty() {
                panic!("NNTRAINER_STRESS_SEEDS={s:?} names no seeds");
            }
            seeds
        }
        Err(std::env::VarError::NotPresent) => vec![20260731],
        Err(e) => panic!("NNTRAINER_STRESS_SEEDS is set but unreadable: {e}"),
    }
}

fn env_stores() -> Vec<StoreKind> {
    match std::env::var("NNTRAINER_STRESS_STORE") {
        Ok(v) => match v.trim() {
            "both" => vec![StoreKind::Host, StoreKind::File],
            "all" => vec![StoreKind::Host, StoreKind::File, StoreKind::FileCompressed],
            other => vec![StoreKind::parse(other).unwrap_or_else(|| {
                panic!(
                    "NNTRAINER_STRESS_STORE={other:?} \
                     (use host|file|file-compressed|both|all)"
                )
            })],
        },
        Err(std::env::VarError::NotPresent) => vec![StoreKind::Host, StoreKind::File],
        Err(e) => panic!("NNTRAINER_STRESS_STORE is set but unreadable: {e}"),
    }
}

/// Per-sample pipelining: forced on/off, or alternating across samples.
#[derive(Clone, Copy)]
enum PipelineMode {
    On,
    Off,
    Mixed,
}

impl PipelineMode {
    fn for_sample(self, sample: usize) -> bool {
        match self {
            PipelineMode::On => true,
            PipelineMode::Off => false,
            // pair with the tuning alternation (sample % 2) so four
            // consecutive samples cover the full tuning x pipeline cross
            PipelineMode::Mixed => (sample / 2) % 2 == 1,
        }
    }
}

fn env_pipeline() -> PipelineMode {
    match std::env::var("NNTRAINER_STRESS_PIPELINE") {
        Ok(v) => match v.trim() {
            "on" | "1" => PipelineMode::On,
            "off" | "0" => PipelineMode::Off,
            "mixed" => PipelineMode::Mixed,
            other => panic!("NNTRAINER_STRESS_PIPELINE={other:?} (use on|off|mixed)"),
        },
        Err(std::env::VarError::NotPresent) => PipelineMode::Mixed,
        Err(e) => panic!("NNTRAINER_STRESS_PIPELINE is set but unreadable: {e}"),
    }
}

fn env_samples() -> usize {
    match std::env::var("NNTRAINER_STRESS_SAMPLES") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            Ok(_) => panic!("NNTRAINER_STRESS_SAMPLES must be > 0"),
            Err(e) => panic!("NNTRAINER_STRESS_SAMPLES={v:?} is not a usize: {e}"),
        },
        Err(std::env::VarError::NotPresent) => 6,
        Err(e) => panic!("NNTRAINER_STRESS_SAMPLES is set but unreadable: {e}"),
    }
}

#[test]
fn randomized_topology_swap_equivalence() {
    let samples = env_samples();
    let pipeline_mode = env_pipeline();
    for &seed in &env_seeds() {
        for &store in &env_stores() {
            for sample in 0..samples {
                // alternate tunings so both engines cover every family
                let tuning = if sample % 2 == 0 { SwapTuning::Fixed } else { SwapTuning::Calibrated };
                run_sample(seed, sample, store, tuning, pipeline_mode.for_sample(sample));
            }
        }
    }
}
