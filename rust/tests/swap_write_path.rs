//! Write-path regression suite for the full-duplex swap engine:
//!
//! * a write ticket whose gap is reclaimed before the store copy lands
//!   must **block** the training thread at the reclaim barrier (counted
//!   as write stall) — never let the tenant corrupt the in-flight data;
//! * dropping the engine mid-epoch (tickets still in flight) must not
//!   deadlock, and teardown must leave the secondary store empty (slot
//!   audit — no leaked eviction slots);
//! * synchronous and asynchronous eviction modes are bitwise identical
//!   (the switch the bench baseline rows use).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use nntrainer::compiler::CompileOpts;
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{Model, ModelBuilder};
use nntrainer::planner::offload::{advise, OffloadEntry, OffloadPlan, PREFETCH_DEPTH};
use nntrainer::planner::MemoryPool;
use nntrainer::rng::Rng;
use nntrainer::runtime::{HostStore, SecondaryStore, SwapExec};
use nntrainer::tensor::{
    CreateMode, Initializer, Lifespan, Region, TensorDim, TensorRole, TensorTable,
};

/// Host store whose writes take `put_delay` — long enough to guarantee
/// a reclaim barrier finds the ticket still in flight.
struct SlowStore {
    inner: HostStore,
    put_delay: Duration,
}

impl SlowStore {
    fn new(put_delay: Duration) -> Self {
        SlowStore { inner: HostStore::new(), put_delay }
    }
}

impl SecondaryStore for SlowStore {
    fn kind(&self) -> &'static str {
        "slow-host"
    }
    fn put(&mut self, key: usize, data: &[f32]) -> nntrainer::Result<()> {
        std::thread::sleep(self.put_delay);
        self.inner.put(key, data)
    }
    fn get(&mut self, key: usize, out: &mut [f32]) -> nntrainer::Result<()> {
        self.inner.get(key, out)
    }
    fn free(&mut self, key: usize) {
        self.inner.free(key);
    }
    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }
}

/// Two tensors sharing one address range: `a` idles over EOs (0, 6) and
/// is offloaded; tenant `b` lives at EOs 2..3 inside the gap.
fn shared_range_setup() -> (TensorTable, OffloadPlan, Region) {
    let len = 256usize;
    let mut t = TensorTable::new();
    let a = t
        .request("a", TensorDim::vec(1, len), TensorRole::Activation, CreateMode::Create, Initializer::None)
        .unwrap();
    t.add_eo(a, 0, Lifespan::FORWARD);
    t.add_eo(a, 6, Lifespan::FORWARD);
    let b = t
        .request("b", TensorDim::vec(1, len), TensorRole::Activation, CreateMode::Create, Initializer::None)
        .unwrap();
    t.add_eo(b, 2, Lifespan::FORWARD);
    t.add_eo(b, 3, Lifespan::FORWARD);
    t.finish_orders();
    let region = Region { offset: 0, len };
    t.get_mut(a).region = Some(region);
    t.get_mut(b).region = Some(region);
    let plan = OffloadPlan {
        entries: vec![OffloadEntry {
            tensor: a,
            name: "a".into(),
            bytes: len * 4,
            evict_after: 0,
            prefetch_before: 6,
            lead: 1,
            write_lead: 0,
            wrap: false,
        }],
        primary_peak_bytes: len * 4,
        swap_bytes_per_iter: 2 * len * 4,
        fits: true,
        prefetch_depth: PREFETCH_DEPTH,
    };
    (t, plan, region)
}

/// The reclaim barrier: with a slow store, the tenant's first use EO
/// arrives before the write ticket lands — the engine must block there
/// (write stall accrues) and the evicted bytes must come back bitwise,
/// untouched by the tenant's writes.
#[test]
fn reclaimed_gap_blocks_until_write_lands() {
    let (t, plan, region) = shared_range_setup();
    let pool = MemoryPool::new(region.len);
    let mut sw = SwapExec::new(
        &t,
        &plan,
        Box::new(SlowStore::new(Duration::from_millis(150))),
        None,
    )
    .unwrap();
    assert_eq!(sw.reclaim_eo_of(0), 2, "tenant placement sets the write barrier");

    // a's live data: a recognizable bit pattern
    let pattern: Vec<f32> = (0..region.len).map(|i| (i as f32) * 0.5 - 7.25).collect();
    pool.view_mut(region).copy_from_slice(&pattern);

    sw.begin_iteration(true, &pool).unwrap();
    sw.pre_step(0, &pool).unwrap();
    sw.check_residency(0).unwrap();
    sw.post_step(0, &pool).unwrap(); // ticket issued, write in flight

    sw.pre_step(1, &pool).unwrap();
    sw.post_step(1, &pool).unwrap();

    // tenant's first use: the barrier must wait out the slow write
    sw.pre_step(2, &pool).unwrap();
    assert!(
        sw.stats.write_stall_ns > 0,
        "reclaim before completion must block (write stall), got {:?}",
        sw.stats
    );
    // now the tenant scribbles over the shared range
    pool.view_mut(region).fill(-7.0);
    sw.post_step(2, &pool).unwrap();
    sw.pre_step(3, &pool).unwrap();
    sw.post_step(3, &pool).unwrap();
    sw.pre_step(4, &pool).unwrap();
    sw.post_step(4, &pool).unwrap();

    // a's read barrier (due = 6 - 1): the store copy comes back bitwise
    sw.pre_step(5, &pool).unwrap();
    sw.check_residency(6).unwrap();
    let restored = pool.view(region);
    for (k, (x, y)) in restored.iter().zip(pattern.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "a[{k}]: {x} vs {y} — tenant writes corrupted the in-flight eviction"
        );
    }
    sw.end_iteration(&pool).unwrap();
    assert_eq!(sw.stats.evictions, 1);
    assert_eq!(sw.stats.prefetches, 1);
}

/// Dropping the engine with a write ticket still in flight must join
/// cleanly (no deadlock, the ticket drains first) and free every store
/// slot — the audit that teardown leaks nothing.
#[test]
fn mid_iteration_drop_joins_and_frees_slots() {
    let (t, plan, region) = shared_range_setup();
    let pool = MemoryPool::new(region.len);
    let sw = SwapExec::new(
        &t,
        &plan,
        Box::new(SlowStore::new(Duration::from_millis(120))),
        None,
    )
    .unwrap();
    let store: Arc<Mutex<Box<dyn SecondaryStore>>> = sw.store_handle();
    let mut sw = sw;
    sw.begin_iteration(true, &pool).unwrap();
    sw.pre_step(0, &pool).unwrap();
    sw.post_step(0, &pool).unwrap(); // write in flight
    drop(sw); // must not deadlock; joins both workers
    assert_eq!(
        store.lock().unwrap().slot_count(),
        0,
        "teardown leaked store slots"
    );
}

fn conv_stack() -> Vec<NodeDesc> {
    let node = |name: &str, ltype: &str, pairs: &[(&str, &str)]| {
        NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
    };
    vec![
        node("in", "input", &[("input_shape", "4:16:16")]),
        node("c0", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c2", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("fc", "fully_connected", &[("unit", "10")]),
        node("loss", "mse", &[]),
    ]
}

fn compile_budget(batch: usize) -> Model {
    let nodes = conv_stack();
    let base = ModelBuilder::new()
        .add_nodes(nodes.clone())
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .compile(&CompileOpts { batch, ..Default::default() })
        .unwrap();
    let full = advise(&base.exec.graph.table, usize::MAX).primary_peak_bytes;
    ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .compile(&CompileOpts {
            batch,
            memory_budget_bytes: Some(full * 75 / 100),
            ..Default::default()
        })
        .unwrap()
}

/// Model-level teardown audit: after real budgeted training (store
/// slots populated by a full epoch of evictions), dropping the model
/// mid-epoch leaves the store empty.
#[test]
fn model_drop_after_training_frees_all_slots() {
    let batch = 8usize;
    let mut m = compile_budget(batch);
    let sw = m.exec.swap_mut().expect("swap runtime engaged");
    assert!(sw.n_entries() > 0);
    let store = sw.store_handle();
    let in_len: usize = m
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len: usize = m
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    let input = vec![0.25f32; in_len * batch];
    let label = vec![0.5f32; lb_len * batch];
    for _ in 0..2 {
        m.bind_batch(&input, &label).unwrap();
        m.exec.try_train_iteration().unwrap();
    }
    assert!(
        store.lock().unwrap().slot_count() > 0,
        "training under a budget should have populated store slots"
    );
    drop(m);
    assert_eq!(
        store.lock().unwrap().slot_count(),
        0,
        "model teardown leaked store slots"
    );
}

/// The eviction mode only moves *when* the store copy happens:
/// synchronous (training-thread) and asynchronous (write-ticket)
/// evictions must train bitwise identically.
#[test]
fn sync_and_async_evictions_are_bitwise_identical() {
    let batch = 8usize;
    let mut sync = compile_budget(batch);
    sync.exec
        .swap_mut()
        .unwrap()
        .set_sync_evictions(true);
    let mut async_ = compile_budget(batch);

    let in_len: usize = sync
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| sync.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len: usize = sync
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| sync.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    let mut rng = Rng::new(0xFEED);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    for it in 0..3 {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        sync.bind_batch(&input, &label).unwrap();
        async_.bind_batch(&input, &label).unwrap();
        let l0 = sync.exec.try_train_iteration().unwrap();
        let l1 = async_.exec.try_train_iteration().unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "iteration {it}: {l0} vs {l1}");
    }
    for w in sync.exec.weight_names() {
        let a = sync.exec.read_weight(&w).unwrap();
        let b = async_.exec.read_weight(&w).unwrap();
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{w}[{k}]: {x} vs {y}");
        }
    }
}
