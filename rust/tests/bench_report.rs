//! Perf-harness suite: the hand-rolled JSON emitter must round-trip
//! (golden snapshot included), the diff gate must fire on a synthetic
//! regression and stay quiet inside the threshold, a missing baseline
//! must not fail a first run, non-comparable baselines (hand-seeded or
//! differently-sized) must stay informational — plus the two bench-path
//! regressions the harness would have caught: every epoch must train on
//! a fresh batch sequence, and the swap runtime must expose per-epoch
//! stat snapshots that sum back to the cumulative counters.

use std::path::PathBuf;

use nntrainer::bench_report::{
    diff, finish_in, BenchReport, Gate, Metric, Source,
};
use nntrainer::bench_util::{budget_profile, nntrainer_profile, plan, train_random_run};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::runtime::SwapStats;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

fn mlp() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:64")]),
        node("h0", "fully_connected", &[("unit", "32"), ("activation", "relu")]),
        node("out", "fully_connected", &[("unit", "4")]),
        node("loss", "mse", &[]),
    ]
}

/// Conv stack whose idle activations dominate — forces a swap plan at a
/// 70% budget (the swap-equivalence suite's classic offload case).
fn conv_stack() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "4:16:16")]),
        node("c0", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c2", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("fc", "fully_connected", &[("unit", "10")]),
        node("loss", "mse", &[]),
    ]
}

fn sample_report() -> BenchReport {
    let mut r = BenchReport::new("sample", 32);
    r.push(
        "LeNet-5/gapfit/host/fixed/async",
        vec![
            Metric::lower("step_latency_ms", 12.5),
            Metric::higher("iters_per_s", 80.0),
            Metric::lower("frag_pct", 0.0),
            Metric::info("depth", 2.0),
        ],
    );
    r.push(
        "quoted \"name\" \\ with unicode Δ",
        vec![Metric::lower("advised_mib", 3.75), Metric::info("nan_metric", f64::NAN)],
    );
    r
}

// ------------------------------------------------------------ emitter

#[test]
fn json_round_trips() {
    let r = sample_report();
    let text = r.to_json();
    let back = BenchReport::from_json(&text).expect("round-trip parse");
    assert_eq!(back.name, r.name);
    assert_eq!(back.dataset, r.dataset);
    assert_eq!(back.source, Source::Measured);
    assert_eq!(back.rows.len(), r.rows.len());
    for (a, b) in r.rows.iter().zip(back.rows.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.metrics.len(), b.metrics.len());
        for (ma, mb) in a.metrics.iter().zip(b.metrics.iter()) {
            assert_eq!(ma.name, mb.name);
            assert_eq!(ma.gate, mb.gate);
            if ma.value.is_finite() {
                assert_eq!(ma.value, mb.value, "{}/{}", a.id, ma.name);
            } else {
                // non-finite values round-trip through JSON null
                assert!(mb.value.is_nan());
            }
        }
    }
    // and a second emit is byte-identical (stable snapshots diff cleanly)
    assert_eq!(text, back.to_json());
}

#[test]
fn golden_snapshot_parses() {
    // hand-written in the committed-baseline shape: whitespace quirks,
    // escapes, a seeded source, an integer-valued metric and a null
    let golden = r#"{
        "name": "fig9", "dataset": 0, "source": "seeded",
        "rows": [
            { "id": "Model A (Linear)",
              "metrics": [
                { "name": "pool_mib", "value": 183, "gate": "lower" },
                { "name": "ratio_incl_tf_x", "value": 3.25, "gate": "info" },
                { "name": "quoteA\"esc\"", "value": null, "gate": "higher" }
              ] }
        ]
    }"#;
    let r = BenchReport::from_json(golden).expect("golden parses");
    assert_eq!(r.name, "fig9");
    assert_eq!(r.dataset, 0);
    assert_eq!(r.source, Source::Seeded);
    assert_eq!(r.rows.len(), 1);
    let ms = &r.rows[0].metrics;
    assert_eq!(ms[0].value, 183.0);
    assert_eq!(ms[0].gate, Gate::Lower);
    assert_eq!(ms[2].name, "quoteA\"esc\"");
    assert!(ms[2].value.is_nan());
}

#[test]
fn malformed_json_is_a_loud_error() {
    for bad in [
        "",
        "{",
        "{\"name\": \"x\"}",
        "{\"name\": \"x\", \"dataset\": -1, \"source\": \"measured\", \"rows\": []}",
        "{\"name\": \"x\", \"dataset\": 1, \"source\": \"banana\", \"rows\": []}",
        "{\"name\": \"x\", \"dataset\": 1, \"source\": \"measured\", \"rows\": [{}]}",
        "{\"name\": \"x\", \"dataset\": 1, \"source\": \"measured\", \"rows\": []} trailing",
    ] {
        assert!(BenchReport::from_json(bad).is_err(), "accepted: {bad:?}");
    }
}

// --------------------------------------------------------------- gate

#[test]
fn gate_fires_on_synthetic_regression() {
    let base = sample_report();
    // +12% step latency on one row: past the 10% default threshold
    let mut cur = sample_report();
    cur.rows[0].metrics[0].value = 12.5 * 1.12;
    let d = diff(&base, &cur, 10.0);
    let regs = d.regressions();
    assert_eq!(regs.len(), 1, "{:?}", d.deltas);
    assert_eq!(regs[0].metric, "step_latency_ms");
    assert!(regs[0].change_pct > 10.0 && regs[0].change_pct < 14.0);
    // the rendered table marks it
    assert!(d.render().contains("REGRESSED"), "{}", d.render());
}

#[test]
fn gate_quiet_inside_threshold() {
    let base = sample_report();
    let mut cur = sample_report();
    cur.rows[0].metrics[0].value = 12.5 * 1.09; // +9% < 10%
    assert!(diff(&base, &cur, 10.0).regressions().is_empty());
    // and an identical run never regresses
    assert!(diff(&base, &base, 10.0).regressions().is_empty());
}

#[test]
fn gate_fires_on_throughput_drop() {
    // higher-is-better metrics regress downward
    let base = sample_report();
    let mut cur = sample_report();
    cur.rows[0].metrics[1].value = 80.0 * 0.85; // -15% iters/s
    let regs_metric = {
        let d = diff(&base, &cur, 10.0);
        let regs = d.regressions();
        assert_eq!(regs.len(), 1);
        regs[0].metric.clone()
    };
    assert_eq!(regs_metric, "iters_per_s");
}

#[test]
fn info_metrics_and_zero_baselines_never_gate() {
    let base = sample_report();
    let mut cur = sample_report();
    cur.rows[0].metrics[3].value = 1000.0; // info: depth exploded
    cur.rows[0].metrics[2].value = 50.0; // gated, but baseline frag is 0.0
    cur.rows[1].metrics[1].value = 1.0; // baseline is NaN
    assert!(diff(&base, &cur, 10.0).regressions().is_empty());
}

#[test]
fn seeded_or_mismatched_baselines_are_informational() {
    let mut seeded = sample_report();
    seeded.source = Source::Seeded;
    let mut cur = sample_report();
    cur.rows[0].metrics[0].value = 1e6; // wildly regressed
    let d = diff(&seeded, &cur, 10.0);
    assert!(!d.gate_applies);
    assert!(d.regressions().is_empty());
    assert!(d.gate_note.is_some());

    let base = sample_report(); // dataset 32
    let mut cur2 = sample_report();
    cur2.dataset = 128;
    cur2.rows[0].metrics[0].value = 1e6;
    let d2 = diff(&base, &cur2, 10.0);
    assert!(!d2.gate_applies);
    assert!(d2.regressions().is_empty());
}

#[test]
fn row_churn_is_noted_not_gated() {
    let base = sample_report();
    let mut cur = sample_report();
    cur.rows[0].id = "renamed".into();
    let d = diff(&base, &cur, 10.0);
    assert_eq!(d.missing_rows, vec!["LeNet-5/gapfit/host/fixed/async".to_string()]);
    assert_eq!(d.new_rows, vec!["renamed".to_string()]);
    assert!(d.regressions().is_empty());
}

// -------------------------------------------------------------- driver

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("nntrainer_bench_report_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

#[test]
fn first_run_tolerates_missing_baseline_then_diffs() {
    let dir = temp_dir("first_run");
    let path = dir.join("BENCH_sample.json");
    let _ = std::fs::remove_file(&path);
    let r = sample_report();
    // no baseline: must not panic/exit, and must leave a valid snapshot
    finish_in(&r, &dir);
    let written = std::fs::read_to_string(&path).expect("snapshot written");
    let parsed = BenchReport::from_json(&written).expect("snapshot parses");
    assert_eq!(parsed.rows.len(), r.rows.len());
    // second run now diffs against it — identical numbers, still alive
    finish_in(&r, &dir);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_overwrites_keep_latest_run() {
    let dir = temp_dir("overwrite");
    let path = dir.join("BENCH_sample.json");
    let _ = std::fs::remove_file(&path);
    let r = sample_report();
    finish_in(&r, &dir);
    let mut faster = sample_report();
    faster.rows[0].metrics[0].value = 10.0; // improved — never gates
    finish_in(&faster, &dir);
    let latest = BenchReport::from_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(latest.rows[0].metrics[0].value, 10.0);
    let _ = std::fs::remove_file(&path);
}

// ------------------------------------------------- bench-path regressions

#[test]
fn epochs_see_distinct_batches() {
    // lr = 0 keeps the weights frozen, so the per-epoch mean loss is a
    // pure function of the epoch's data: equal losses == replayed
    // batches (the silent bug: every epoch re-seeded the producer with
    // the same constant, so every epoch trained on epoch 0's sequence)
    let (_m, _s, iters, losses) =
        train_random_run(mlp(), &nntrainer_profile(4), 16, 3, 0.0, false).expect("train");
    assert_eq!(losses.len(), 3);
    assert_eq!(iters, 12);
    assert_ne!(losses[0], losses[1], "epoch 1 replayed epoch 0's batches");
    assert_ne!(losses[1], losses[2], "epoch 2 replayed epoch 1's batches");
}

#[test]
fn swap_epoch_stats_sum_to_cumulative() {
    let base = plan(conv_stack(), &nntrainer_profile(8)).expect("plan");
    let target = base.pool_bytes * 75 / 100;
    let (model, _secs, iters, _losses) =
        train_random_run(conv_stack(), &budget_profile(8, target), 16, 2, 0.01, false)
            .expect("train under budget");
    assert!(iters >= 4, "expected 2 epochs x 2 iters, got {iters}");
    let cum = model.exec.swap_stats().expect("swap runtime active");
    assert!(cum.evictions > 0, "budget did not engage the swap runtime");
    let per = model.exec.swap_epoch_stats().expect("swap runtime active");
    assert_eq!(per.len(), 2, "one snapshot per epoch boundary");
    let fields: [(&str, fn(&SwapStats) -> u64); 7] = [
        ("evictions", |s| s.evictions),
        ("prefetches", |s| s.prefetches),
        ("sync_fetches", |s| s.sync_fetches),
        ("bytes_out", |s| s.bytes_out),
        ("bytes_in", |s| s.bytes_in),
        ("read_stall_ns", |s| s.read_stall_ns),
        ("write_stall_ns", |s| s.write_stall_ns),
    ];
    for (label, field) in fields {
        assert_eq!(
            per.iter().map(|s| field(s)).sum::<u64>(),
            field(&cum),
            "{label}: per-epoch deltas must partition the cumulative counters"
        );
    }
    // both epochs actually moved bytes — the trajectory is per-epoch
    assert!(per.iter().all(|s| s.bytes_out > 0), "{per:?}");
}
