//! Swap-equivalence suite: training under a tight primary-memory budget
//! through the proactive swap runtime must be **bitwise identical** to
//! training without swapping. The swap runtime only moves bytes — every
//! evicted tensor comes back with the exact representation it left with,
//! at a deterministic point in the step order — so losses and weights
//! must match bit for bit, not merely to a tolerance.
//!
//! Also covers the end-to-end acceptance scenario (a model whose
//! unswapped peak exceeds the budget trains under it, with the realized
//! pool at or under the advised peak plus slack) and the
//! deliberately-corrupted-plan negative test for the residency guard.

use nntrainer::compiler::CompileOpts;
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{zoo, Model, ModelBuilder};
use nntrainer::planner::offload::advise;
use nntrainer::rng::Rng;
use nntrainer::runtime::{StoreKind, SwapTuning};

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

/// Conv stack whose idle activations dominate — the classic offload case.
fn conv_stack() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "4:16:16")]),
        node("c0", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c2", "conv2d", &[("filters", "16"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("fc", "fully_connected", &[("unit", "10")]),
        node("loss", "mse", &[]),
    ]
}

fn mlp() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "1:1:128")]),
        node("h0", "fully_connected", &[("unit", "256"), ("activation", "relu")]),
        node("h1", "fully_connected", &[("unit", "256"), ("activation", "relu")]),
        node("out", "fully_connected", &[("unit", "10")]),
        node("loss", "mse", &[]),
    ]
}

fn compile(nodes: Vec<NodeDesc>, opts: &CompileOpts) -> Model {
    ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", "0.05")])
        .compile(opts)
        .unwrap()
}

fn feat_lens(m: &Model) -> (usize, usize) {
    let in_len = m
        .exec
        .graph
        .input_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].out_dims[0].feature_len())
        .sum();
    let lb_len = m
        .exec
        .graph
        .loss_nodes
        .iter()
        .map(|&n| m.exec.graph.nodes[n].in_dims[0].feature_len())
        .sum();
    (in_len, lb_len)
}

/// Train `iters` iterations with identical data on an unswapped and a
/// budgeted (swap-runtime) instance of the same model; assert bitwise
/// identical losses and weights throughout.
fn assert_swap_equivalence(
    nodes: fn() -> Vec<NodeDesc>,
    batch: usize,
    budget_pct: usize,
    iters: usize,
    store: StoreKind,
    tuning: SwapTuning,
) {
    let base_opts = CompileOpts { batch, ..Default::default() };
    let mut base = compile(nodes(), &base_opts);
    let full = advise(&base.exec.graph.table, usize::MAX).primary_peak_bytes;
    let budget = full * budget_pct / 100;

    let mut swapped = compile(
        nodes(),
        &CompileOpts {
            batch,
            memory_budget_bytes: Some(budget),
            swap_store: store,
            swap_tuning: tuning,
            ..Default::default()
        },
    );
    assert!(swapped.exec.swap_active());
    let plan = swapped.exec.swap_plan().unwrap().clone();
    assert!(
        !plan.entries.is_empty(),
        "budget {budget} of peak {full} produced no offloads"
    );

    let (in_len, lb_len) = feat_lens(&base);
    let mut rng = Rng::new(0xC0FFEE);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    for it in 0..iters {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        base.bind_batch(&input, &label).unwrap();
        swapped.bind_batch(&input, &label).unwrap();
        let l0 = base.exec.try_train_iteration().unwrap();
        let l1 = swapped.exec.try_train_iteration().unwrap();
        assert_eq!(
            l0.to_bits(),
            l1.to_bits(),
            "iteration {it}: loss diverged ({l0} vs {l1})"
        );
    }

    for w in base.exec.weight_names() {
        let a = base.exec.read_weight(&w).unwrap();
        let b = swapped.exec.read_weight(&w).unwrap();
        assert_eq!(a.len(), b.len(), "{w}: length");
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{w}[{k}]: {x} vs {y} after {iters} iterations"
            );
        }
    }

    // swapping actually happened, and symmetrically
    let stats = swapped.exec.swap_stats().unwrap();
    assert!(stats.bytes_out > 0, "no eviction traffic: {stats:?}");
    assert_eq!(stats.bytes_out, stats.bytes_in, "swap traffic asymmetric: {stats:?}");
    assert_eq!(
        stats.bytes_out,
        iters as u64 * (plan.swap_bytes_per_iter / 2) as u64,
        "traffic does not match the advised per-iteration swap bytes"
    );
}

#[test]
fn conv_stack_equivalence_host_store() {
    assert_swap_equivalence(conv_stack, 8, 75, 4, StoreKind::Host, SwapTuning::Fixed);
}

#[test]
fn mlp_equivalence_host_store() {
    assert_swap_equivalence(mlp, 16, 85, 4, StoreKind::Host, SwapTuning::Fixed);
}

#[test]
fn lenet_equivalence_file_store() {
    assert_swap_equivalence(zoo::lenet5, 8, 85, 2, StoreKind::File, SwapTuning::Fixed);
}

/// Calibrated tuning moves *when* the background copies happen (derived
/// leads/depth, warmup re-derivation after 2 iterations) — never what
/// they contain. Training must stay bitwise identical to unswapped on
/// both store kinds, across the warmup→recalibrated transition.
#[test]
fn conv_stack_equivalence_calibrated_host_store() {
    assert_swap_equivalence(conv_stack, 8, 75, 4, StoreKind::Host, SwapTuning::Calibrated);
}

#[test]
fn lenet_equivalence_calibrated_file_store() {
    assert_swap_equivalence(zoo::lenet5, 8, 85, 4, StoreKind::File, SwapTuning::Calibrated);
}

/// End-to-end acceptance: the unswapped peak exceeds the budget, the
/// budgeted compile fits, the realized pool stays within the advised
/// peak plus planner slack, and training under the budget converges.
#[test]
fn trains_under_budget_with_realized_peak() {
    let batch = 16usize;
    let base = compile(conv_stack(), &CompileOpts { batch, ..Default::default() });
    let full = advise(&base.exec.graph.table, usize::MAX).primary_peak_bytes;
    let budget = full * 75 / 100;
    assert!(base.peak_pool_bytes() > budget, "budget is not actually tight");

    let mut m = compile(
        conv_stack(),
        &CompileOpts { batch, memory_budget_bytes: Some(budget), ..Default::default() },
    );
    let plan = m.exec.swap_plan().unwrap().clone();
    assert!(plan.fits, "advisor could not meet 75% budget: {plan:?}");
    assert!(plan.primary_peak_bytes <= budget);

    // realized pool ≤ advised live-set peak + first-fit slack
    let realized = m.peak_pool_bytes();
    let slack = plan.primary_peak_bytes / 4 + 4096;
    assert!(
        realized <= plan.primary_peak_bytes + slack,
        "realized pool {realized} vs advised {} (+{slack} slack)",
        plan.primary_peak_bytes
    );
    assert!(realized < full, "pool did not shrink below the unswapped peak");

    // and it really trains under that pool
    // overfit one fixed batch: the loss must strictly shrink
    let (in_len, lb_len) = feat_lens(&m);
    let mut rng = Rng::new(7);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    rng.fill_uniform(&mut input, -1.0, 1.0);
    rng.fill_uniform(&mut label, 0.0, 1.0);
    let mut first = f32::INFINITY;
    let mut last = f32::INFINITY;
    for it in 0..30 {
        m.bind_batch(&input, &label).unwrap();
        last = m.exec.try_train_iteration().unwrap();
        if it == 0 {
            first = last;
        }
    }
    assert!(
        last.is_finite() && last < first,
        "training under budget did not make progress: {first} -> {last}"
    );

    // forward-only passes engage the swap protocol too (the budgeted
    // pool aliases regions across idle gaps): inference must still work
    let out = m.infer(&input).unwrap();
    assert!(!out.is_empty());
    assert!(out.iter().all(|v| v.is_finite()), "inference under budget produced non-finite output");
}

/// Negative test: corrupt the schedule so one tensor's prefetch never
/// lands before its next use — the executor's residency guard must fail
/// the iteration instead of computing on evicted data.
#[test]
fn corrupted_plan_trips_residency_guard() {
    let batch = 8usize;
    let base = compile(conv_stack(), &CompileOpts { batch, ..Default::default() });
    let full = advise(&base.exec.graph.table, usize::MAX).primary_peak_bytes;

    let mut m = compile(
        conv_stack(),
        &CompileOpts {
            batch,
            memory_budget_bytes: Some(full * 75 / 100),
            ..Default::default()
        },
    );
    let sw = m.exec.swap_mut().unwrap();
    assert!(sw.n_entries() > 0);
    sw.delay_prefetch_for_test(0, u32::MAX);

    let (in_len, lb_len) = feat_lens(&m);
    let input = vec![0.5f32; in_len * batch];
    let label = vec![0.5f32; lb_len * batch];
    m.bind_batch(&input, &label).unwrap();
    let err = m.exec.try_train_iteration().unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("residency violation"),
        "expected a residency violation, got: {msg}"
    );
}

/// Regression for the schedule-head saturation edge: shrink one entry's
/// gap to a single EO so its completion barrier fires at (or before)
/// its own eviction step. The old runtime marked the entry restored in
/// that pre-step ("gap never opened"), the eviction then stranded the
/// data in the store, and from iteration 2 on training silently read
/// whatever the gap tenant left in the region. The runtime must instead
/// fail the iteration loudly.
#[test]
fn barrier_before_eviction_fails_loudly() {
    let batch = 8usize;
    let base = compile(conv_stack(), &CompileOpts { batch, ..Default::default() });
    let full = advise(&base.exec.graph.table, usize::MAX).primary_peak_bytes;

    let mut m = compile(
        conv_stack(),
        &CompileOpts {
            batch,
            memory_budget_bytes: Some(full * 75 / 100),
            ..Default::default()
        },
    );
    let sw = m.exec.swap_mut().unwrap();
    assert!(sw.n_entries() > 0);
    // corrupt entry 0 into a 1-EO gap: barrier EO == eviction EO
    let (evict_after, _) = sw.entry_gap(0);
    sw.delay_prefetch_for_test(0, evict_after + 1);

    let (in_len, lb_len) = feat_lens(&m);
    let input = vec![0.5f32; in_len * batch];
    let label = vec![0.5f32; lb_len * batch];
    let mut failed = None;
    // the old code failed *silently*: iteration 1 "succeeded" with the
    // tensor stranded in the store — so run a few and require a loud
    // error before any poisoned result escapes
    for _ in 0..3 {
        m.bind_batch(&input, &label).unwrap();
        if let Err(e) = m.exec.try_train_iteration() {
            failed = Some(e);
            break;
        }
    }
    let msg = failed.expect("1-EO gap must fail loudly, not train on garbage").to_string();
    assert!(
        msg.contains("before its eviction") || msg.contains("residency violation"),
        "unexpected error: {msg}"
    );
}

/// Cross both seams at once: a naive-compute *unswapped* model against
/// the default tiered-compute model running the swap runtime under a
/// tight budget. Neither the worker-pool kernels nor the swap engine
/// may perturb a single bit of the training trajectory, so the two
/// extremes of the configuration space must still agree exactly.
#[test]
fn tiered_swapped_matches_naive_unswapped_bitwise() {
    use nntrainer::backend::ComputeKind;

    let batch = 8usize;
    // budget from the *tiered* unswapped peak, so the budgeted compile
    // below is genuinely forced to offload
    let probe = compile(conv_stack(), &CompileOpts { batch, ..Default::default() });
    let full = advise(&probe.exec.graph.table, usize::MAX).primary_peak_bytes;

    let mut naive = compile(
        conv_stack(),
        &CompileOpts { batch, compute: ComputeKind::Naive, ..Default::default() },
    );
    let mut swapped = compile(
        conv_stack(),
        &CompileOpts { batch, memory_budget_bytes: Some(full * 75 / 100), ..Default::default() },
    );
    assert!(swapped.exec.swap_active());
    assert!(!swapped.exec.swap_plan().unwrap().entries.is_empty());

    let (in_len, lb_len) = feat_lens(&naive);
    let mut rng = Rng::new(0x5EAB17);
    let mut input = vec![0f32; in_len * batch];
    let mut label = vec![0f32; lb_len * batch];
    for it in 0..4 {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        naive.bind_batch(&input, &label).unwrap();
        swapped.bind_batch(&input, &label).unwrap();
        let l0 = naive.exec.try_train_iteration().unwrap();
        let l1 = swapped.exec.try_train_iteration().unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits(), "iteration {it}: loss diverged ({l0} vs {l1})");
    }
    for w in naive.exec.weight_names() {
        let a = naive.exec.read_weight(&w).unwrap();
        let b = swapped.exec.read_weight(&w).unwrap();
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{w}[{i}]: {x} vs {y}");
        }
    }
}
