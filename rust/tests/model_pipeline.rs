//! End-to-end model-pipeline tests: INI → train, checkpoints, transfer
//! learning with a frozen backbone + feature cache, recurrent unrolling
//! with E-shared weights, and the zoo models compiling + planning.

use nntrainer::compiler::unroll::{at, unroll, UnrollSpec};
use nntrainer::compiler::CompileOpts;
use nntrainer::dataset::producer::{CachedProducer, Sample};
use nntrainer::dataset::{DataProducer, DigitsProducer};
use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{ini, zoo, ModelBuilder, TrainConfig};
use nntrainer::planner::PlannerKind;
use nntrainer::tensor::CreateMode;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

#[test]
fn ini_to_training_pipeline() {
    let text = r#"
[Model]
Type = NeuralNetwork
Loss = cross_entropy
Optimizer = sgd
Learning_rate = 0.3
Batch_Size = 8
Epochs = 8

[inputlayer]
Type = input
Input_Shape = 1:16:16

[conv]
Type = conv2d
Filters = 4
Kernel_Size = 3
Padding = same
Activation = relu

[pool]
Type = pooling2d
Pooling = max
Pool_Size = 2

[flat]
Type = flatten

[classifier]
Type = fully_connected
Unit = 10
"#;
    let (builder, hyper) = ini::builder_from_ini(text).unwrap();
    let mut model = builder
        .compile(&CompileOpts { batch: hyper.batch, ..Default::default() })
        .unwrap();
    let make = || -> Box<dyn DataProducer> { Box::new(DigitsProducer::new(80, 16, 1, 5)) };
    let summary = model
        .train(make, &TrainConfig { epochs: hyper.epochs, ..Default::default() })
        .unwrap();
    assert!(
        summary.final_loss < summary.losses_per_epoch[0] * 0.7,
        "digit training did not converge: {:?}",
        summary.losses_per_epoch
    );
}

#[test]
fn checkpoint_roundtrip() {
    let build = || {
        ModelBuilder::new()
            .add_nodes(zoo::mlp_e2e())
            .optimizer("sgd", &[("learning_rate", "0.2")])
            .compile(&CompileOpts { batch: 8, ..Default::default() })
            .unwrap()
    };
    let mut m1 = build();
    let make = || -> Box<dyn DataProducer> { Box::new(DigitsProducer::new(64, 16, 1, 5)) };
    m1.train(make, &TrainConfig { epochs: 2, ..Default::default() }).unwrap();
    let path = "/tmp/nntrainer_ckpt_test.bin";
    m1.save(path).unwrap();

    let mut m2 = build();
    let restored = m2.load(path).unwrap();
    assert!(restored >= 4, "restored only {restored} tensors");
    for w in m1.exec.weight_names() {
        assert_eq!(
            m1.exec.read_weight(&w).unwrap(),
            m2.exec.read_weight(&w).unwrap(),
            "{w} differs after load"
        );
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn checkpoint_rejects_garbage() {
    std::fs::write("/tmp/nntrainer_bad_ckpt.bin", b"not a checkpoint").unwrap();
    let mut m = ModelBuilder::new()
        .add_nodes(zoo::mlp_e2e())
        .optimizer("sgd", &[])
        .compile(&CompileOpts { batch: 4, ..Default::default() })
        .unwrap();
    assert!(m.load("/tmp/nntrainer_bad_ckpt.bin").is_err());
    std::fs::remove_file("/tmp/nntrainer_bad_ckpt.bin").ok();
}

/// Transfer learning (HandMoji flow): train a backbone, freeze it, cache
/// features once, then train only the classifier head on cached features.
#[test]
fn transfer_learning_with_feature_cache() {
    let side = 16usize;
    // 1) "pre-trained" backbone (few steps are enough for the mechanism)
    let mut backbone = ModelBuilder::new()
        .add_nodes(zoo::handmoji_backbone(side))
        .optimizer("sgd", &[("learning_rate", "0.1")])
        .compile(&CompileOpts { batch: 8, ..Default::default() })
        .unwrap();
    let make = || -> Box<dyn DataProducer> { Box::new(DigitsProducer::new(40, 16, 1, 5)) };
    backbone.train(make, &TrainConfig { epochs: 1, ..Default::default() }).unwrap();

    // 2) feature extraction: forward passes over the user's samples,
    //    caching the penultimate ("feat") activations — paper Fig 13's
    //    "cache the results from the feature extractor in the first epoch"
    let mut producer = DigitsProducer::new(40, 16, 1, 77);
    let mut cached = Vec::new();
    for i in 0..producer.len() {
        let s = producer.sample(i);
        // bind one sample replicated over the batch, read features
        let mut batch_in = Vec::new();
        for _ in 0..8 {
            batch_in.extend_from_slice(&s.input);
        }
        backbone.exec.bind_input(0, &batch_in).unwrap();
        backbone.exec.forward_pass();
        let feats = backbone.exec.read_output("feat/activation").unwrap();
        cached.push(Sample { input: feats[..64].to_vec(), label: s.label.clone() });
    }

    // 3) head-only training on cached features
    let mut head = ModelBuilder::new()
        .add_nodes(zoo::handmoji_head(64, 10))
        .optimizer("sgd", &[("learning_rate", "0.5")])
        .compile(&CompileOpts { batch: 8, ..Default::default() })
        .unwrap();
    let cached2 = cached.clone();
    let make_head = move || -> Box<dyn DataProducer> { Box::new(CachedProducer::new(cached2.clone())) };
    let summary = head.train(&make_head, &TrainConfig { epochs: 60, ..Default::default() }).unwrap();
    assert!(
        summary.final_loss < summary.losses_per_epoch[0] * 0.8,
        "head training did not converge: {:?}",
        summary.losses_per_epoch
    );

    // 4) the head model must be tiny compared to full training
    let full = ModelBuilder::new()
        .add_nodes(zoo::handmoji_backbone(side))
        .optimizer("sgd", &[])
        .compile(&CompileOpts { batch: 8, ..Default::default() })
        .unwrap();
    assert!(head.peak_pool_bytes() * 4 < full.peak_pool_bytes());
}

/// Recurrent unrolling: E-mode weight sharing adds no weight memory and
/// accumulates gradients (paper §5.2, Tacotron time iteration).
#[test]
fn unrolled_weights_share_and_accumulate() {
    let step = vec![
        node(
            "cell",
            "fully_connected",
            &[("unit", "6"), ("bias", "false"), ("input_layers", "state")],
        ),
        node("state", "activation", &[("act", "tanh"), ("input_layers", "cell")]),
    ];
    let spec = UnrollSpec { t: 4, recurrent: vec![("state".into(), "state".into())] };
    let unrolled = unroll(&step, &spec).unwrap();
    let mut nodes = vec![
        node("seed", "input", &[("input_shape", "1:1:6")]),
        // initial state named `state` so step-0 wiring finds it
        node("state", "fully_connected", &[("unit", "6"), ("bias", "false"), ("input_layers", "seed")]),
    ];
    nodes.extend(unrolled);
    nodes.push(node(
        "readout",
        "fully_connected",
        &[("unit", "2"), ("input_layers", at("state", 3).as_str())],
    ));
    nodes.push(node("loss", "mse", &[]));

    let model = ModelBuilder::new()
        .add_nodes(nodes)
        .optimizer("sgd", &[("learning_rate", "0.1")])
        .compile(&CompileOpts { batch: 2, ..Default::default() })
        .unwrap();
    let t = &model.exec.graph.table;
    // all unrolled cell weights share storage with step 0
    let root = t.by_name("cell@t0:weight").unwrap();
    for k in 1..4 {
        let wid = t.by_name(&format!("cell@t{k}:weight")).unwrap();
        assert!(matches!(t.get(wid).mode, CreateMode::Extend(_)));
        assert_eq!(t.resolve(wid), root);
        let gid = t.by_name(&format!("cell@t{k}:weight:grad")).unwrap();
        assert_eq!(t.resolve(gid), t.by_name("cell@t0:weight:grad").unwrap());
    }
    // E-sharing forces deferred apply
    assert!(model.exec.deferred_apply);

    // and the whole thing trains
    let mut model = model;
    let mut input = vec![0.1f32; 2 * 6];
    input[3] = 0.9;
    let label = vec![0.3f32, -0.2, 0.1, 0.4];
    model.bind_batch(&input, &label).unwrap();
    let l0 = model.exec.train_iteration();
    for _ in 0..30 {
        model.bind_batch(&input, &label).unwrap();
        model.exec.train_iteration();
    }
    model.bind_batch(&input, &label).unwrap();
    let l1 = model.exec.train_iteration();
    assert!(l1 < l0 * 0.5, "unrolled model did not train: {l0} -> {l1}");
}

/// Every zoo model compiles, plans validly, and reports a plausible peak.
#[test]
fn zoo_models_compile_and_plan() {
    let cases: Vec<(&str, Vec<NodeDesc>, usize)> = vec![
        ("lenet5", zoo::lenet5(), 4),
        ("product_rating", zoo::product_rating(), 4),
        ("tacotron_decoder", zoo::tacotron_decoder(8, 20, 32), 2),
        ("postnet", zoo::postnet(8, 20), 2),
        ("resnet18", zoo::resnet18(), 2),
        ("resnet18_transfer", zoo::resnet18_transfer(), 2),
    ];
    for (name, nodes, batch) in cases {
        let model = ModelBuilder::new()
            .add_nodes(nodes)
            .optimizer("sgd", &[])
            .compile(&CompileOpts { batch, ..Default::default() })
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(model.peak_pool_bytes() > 0, "{name}: zero pool");
        // transfer variant must be smaller than full resnet at same batch
        let _ = model;
    }
}

/// Fig 12's transfer claim: frozen-backbone ResNet peak is below full
/// training, and saves >75 % against the conventional-framework profile
/// (the paper's comparison baseline).
#[test]
fn transfer_resnet_saves_memory() {
    let peak = |nodes, conventional| {
        ModelBuilder::new()
            .add_nodes(nodes)
            .optimizer("sgd", &[])
            .compile(&CompileOpts {
                batch: 4,
                conventional,
                planner: if conventional { PlannerKind::Naive } else { PlannerKind::Sorting },
                ..Default::default()
            })
            .unwrap()
            .peak_pool_bytes()
    };
    let full = peak(zoo::resnet18(), false);
    let transfer = peak(zoo::resnet18_transfer(), false);
    let conventional_full = peak(zoo::resnet18(), true);
    assert!(transfer < full, "transfer {transfer} !< full {full}");
    // >60 % saving on pool bytes alone; the paper's >75 % figure also
    // counts the frameworks' resident baselines (see fig12 bench).
    assert!(
        (transfer as f64) < conventional_full as f64 * 0.4,
        "transfer {transfer} not well below conventional {conventional_full}"
    );
}

/// Batch-size change = recompile (static shapes); larger batch under the
/// planned profile must grow peak sublinearly vs naive (Fig 11's story).
#[test]
fn batch_scaling_sublinear_vs_naive() {
    let peak = |batch: usize, planner: PlannerKind, conventional: bool| {
        ModelBuilder::new()
            .add_nodes(zoo::model_b_linear())
            .optimizer("sgd", &[])
            .compile(&CompileOpts { batch, planner, conventional, ..Default::default() })
            .unwrap()
            .peak_pool_bytes()
    };
    let planned = peak(16, PlannerKind::Sorting, false);
    let naive = peak(16, PlannerKind::Naive, true);
    assert!(planned < naive, "planned {planned} !< naive {naive}");
}

/// Every shipped INI config loads, compiles and plans.
#[test]
fn shipped_configs_compile() {
    for path in ["configs/lenet5.ini", "configs/handmoji_head.ini", "configs/gru_seq.ini"] {
        let (builder, hyper) = ini::builder_from_file(path).unwrap_or_else(|e| panic!("{path}: {e}"));
        let model = builder
            .compile(&CompileOpts { batch: hyper.batch, ..Default::default() })
            .unwrap_or_else(|e| panic!("{path}: {e}"));
        assert!(model.peak_pool_bytes() > 0, "{path}");
    }
}

/// GRU trains on the sequence task end to end (roadmap extension).
#[test]
fn gru_trains_on_sequences() {
    use nntrainer::dataset::SeqProducer;
    let (builder, hyper) = ini::builder_from_file("configs/gru_seq.ini").unwrap();
    let mut model = builder
        .compile(&CompileOpts { batch: hyper.batch, ..Default::default() })
        .unwrap();
    let make = || -> Box<dyn DataProducer> { Box::new(SeqProducer::new(64, 20, 4, 1, 11)) };
    let summary = model
        .train(make, &TrainConfig { epochs: 8, ..Default::default() })
        .unwrap();
    assert!(
        summary.final_loss < summary.losses_per_epoch[0] * 0.5,
        "gru did not converge: {:?}",
        summary.losses_per_epoch
    );
}
