//! Checkpoint-format suite: the NNTR v2 manifest, the strict
//! name/shape-diff load, the `personalize()` head-swap allow-list, and
//! clean failure on truncated/corrupted files (the `read_u32`-trusting
//! loader used to attempt whatever allocation a corrupted length field
//! asked for, and silently skipped unknown layer names).

use std::fs::File;
use std::io::Write;

use nntrainer::dataset::{DataProducer, RandomProducer};
use nntrainer::model::checkpoint;
use nntrainer::model::session::{DeviceProfile, PersonalizeOpts, Session, TrainSpec};
use nntrainer::model::ModelBuilder;
use nntrainer::Error;

fn mlp(head_unit: usize, head_name: &str) -> Session {
    Session::builder()
        .add("in", "input", &[("input_shape", "1:1:16")])
        .add("h0", "fully_connected", &[("unit", "24"), ("activation", "relu")])
        .add(head_name, "fully_connected", &[("unit", &head_unit.to_string())])
        .add("loss", "mse", &[])
        .optimizer("sgd", &[("learning_rate", "0.05")])
}

fn compiled(head_unit: usize, head_name: &str) -> nntrainer::model::session::CompiledSession {
    mlp(head_unit, head_name)
        .configure(TrainSpec { batch: Some(4), ..Default::default() })
        .compile_for(DeviceProfile::unconstrained())
        .unwrap()
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("ckpt_format_{}_{}", std::process::id(), name))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn v2_roundtrip_with_manifest() {
    let a = compiled(8, "out");
    let path = tmp("roundtrip");
    a.save(&path).unwrap();

    // the manifest names every weight with its shape, before any data
    let manifest = checkpoint::read_manifest(&path).unwrap();
    let mut names: Vec<String> = manifest.iter().map(|m| m.name.clone()).collect();
    let mut expect = a.model.exec.weight_names();
    names.sort();
    expect.sort();
    assert_eq!(names, expect);
    for m in &manifest {
        assert_eq!(m.dim.len(), m.len, "manifest dims disagree with data length");
    }

    // bitwise round trip into a freshly initialized twin
    let mut b = compiled(8, "out");
    let restored = b.load(&path).unwrap();
    assert_eq!(restored, manifest.len());
    for w in a.model.exec.weight_names() {
        let x = a.model.exec.read_weight(&w).unwrap();
        let y = b.model.exec.read_weight(&w).unwrap();
        assert_eq!(x.len(), y.len());
        for (p, q) in x.iter().zip(y.iter()) {
            assert_eq!(p.to_bits(), q.to_bits(), "{w} diverged");
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn mismatched_head_fails_with_shape_diff() {
    let a = compiled(8, "out");
    let path = tmp("shape_diff");
    a.save(&path).unwrap();

    // same names, different head width: strict load must diff, not skip
    let mut b = compiled(4, "out");
    let err = b.load(&path).unwrap_err().to_string();
    assert!(err.contains("out:"), "diff does not name the tensor: {err}");
    assert!(err.contains("expects"), "diff does not show the model side: {err}");

    // renamed head: the checkpoint tensor is unknown to the model
    let mut c = compiled(8, "head");
    let err = c.load(&path).unwrap_err().to_string();
    assert!(err.contains("no such weight"), "unknown name not diffed: {err}");

    // the old behaviour (silently restoring only what matches) is now
    // opt-in via the allow-list — backbone restores, head stays local
    let restored =
        checkpoint::load_matching(&c.model.exec, &path, &["out".into()]).unwrap();
    assert!(restored > 0);
    for w in a.model.exec.weight_names() {
        if w.starts_with("h0") {
            let x = a.model.exec.read_weight(&w).unwrap();
            let y = c.model.exec.read_weight(&w).unwrap();
            for (p, q) in x.iter().zip(y.iter()) {
                assert_eq!(p.to_bits(), q.to_bits(), "backbone {w} not restored");
            }
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// The paper's §5 flow with a swapped head of a *different shape*: the
/// reinit prefixes double as the load allow-list, so the head-swap
/// works while an unexpected mismatch (no reinit declared) fails with
/// the diff instead of silently fine-tuning from random init.
#[test]
fn personalize_head_swap_uses_allow_list() {
    let vendor = compiled(8, "out");
    let path = tmp("personalize");
    vendor.save(&path).unwrap();

    let make = || -> Box<dyn DataProducer> { Box::new(RandomProducer::new(16, 16, 4, 7)) };

    // head widened 8 → 4: personalize declares the swap, so the
    // backbone restores and training proceeds
    let mut user = compiled(4, "out");
    let report = user
        .personalize(
            &PersonalizeOpts {
                checkpoint: Some(path.clone()),
                reinit: vec!["out".into()],
                ..Default::default()
            },
            make,
            &mut [],
        )
        .unwrap();
    assert!(report.restored > 0, "backbone not restored");
    assert!(report.reinitialized > 0, "head not reinitialized");

    // no reinit declared: the mismatch must fail loudly with the diff
    let mut user2 = compiled(4, "out");
    let err = user2
        .personalize(
            &PersonalizeOpts { checkpoint: Some(path.clone()), ..Default::default() },
            make,
            &mut [],
        )
        .unwrap_err()
        .to_string();
    assert!(err.contains("does not match"), "no diff in: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupted_lengths_error_cleanly() {
    let a = compiled(8, "out");
    let path = tmp("corrupt");
    a.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // truncate mid-data: load must report truncation, not garbage
    let cut = tmp("truncated");
    std::fs::write(&cut, &bytes[..bytes.len() - 7]).unwrap();
    let b = compiled(8, "out");
    let err = checkpoint::load(&b.model.exec, &cut).unwrap_err().to_string();
    assert!(
        err.contains("truncated") || err.contains("remain") || err.contains("claims"),
        "truncation not detected: {err}"
    );

    // corrupt a manifest length field to u32::MAX: the claimed size
    // exceeds the file, so the loader must refuse *before* allocating
    let huge = tmp("huge_len");
    let mut doctored = bytes.clone();
    // first manifest entry: magic(4) + version(4) + count(4) = offset 12,
    // then name-len at 12; dims at 12 + 4 + nlen; dlen 16 bytes later
    let nlen = u32::from_le_bytes([bytes[12], bytes[13], bytes[14], bytes[15]]) as usize;
    let dlen_off = 12 + 4 + nlen + 16;
    doctored[dlen_off..dlen_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    std::fs::write(&huge, &doctored).unwrap();
    let err = checkpoint::load(&b.model.exec, &huge).unwrap_err().to_string();
    assert!(
        err.contains("claims") || err.contains("remain"),
        "oversized length not rejected: {err}"
    );

    for p in [path, cut, huge] {
        let _ = std::fs::remove_file(&p);
    }
}

/// Legacy v1 files (no manifest) still load, with lengths validated and
/// mismatches now failing instead of skipping.
#[test]
fn v1_files_still_load() {
    let a = compiled(8, "out");
    let path = tmp("v1");
    // hand-write a v1 checkpoint for one real weight
    let name = "h0:weight";
    let data = a.model.exec.read_weight(name).unwrap();
    {
        let mut f = File::create(&path).unwrap();
        f.write_all(b"NNTR").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&(name.len() as u32).to_le_bytes()).unwrap();
        f.write_all(name.as_bytes()).unwrap();
        f.write_all(&(data.len() as u32).to_le_bytes()).unwrap();
        for v in &data {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
    }
    let mut b = compiled(8, "out");
    // scramble the target first so the restore is observable
    b.model
        .exec
        .write_weight(name, &vec![0.25f32; data.len()])
        .unwrap();
    assert_eq!(b.load(&path).unwrap(), 1);
    let y = b.model.exec.read_weight(name).unwrap();
    for (p, q) in data.iter().zip(y.iter()) {
        assert_eq!(p.to_bits(), q.to_bits());
    }

    // a v1 entry the model does not know must now error, not skip
    let unk = tmp("v1_unknown");
    {
        let mut f = File::create(&unk).unwrap();
        f.write_all(b"NNTR").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        let bad = "ghost:weight";
        f.write_all(&(bad.len() as u32).to_le_bytes()).unwrap();
        f.write_all(bad.as_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&1.0f32.to_le_bytes()).unwrap();
        f.write_all(&2.0f32.to_le_bytes()).unwrap();
    }
    let err = checkpoint::load(&b.model.exec, &unk).unwrap_err().to_string();
    assert!(err.contains("no such weight"), "v1 unknown name skipped: {err}");

    for p in [path, unk] {
        let _ = std::fs::remove_file(&p);
    }
}

/// `Error::Checkpoint` is what all of the above surface as — make the
/// variant's path explicit so a refactor cannot quietly reroute these
/// failures through a generic error.
#[test]
fn checkpoint_errors_use_checkpoint_variant() {
    let path = tmp("not_a_checkpoint");
    std::fs::write(&path, b"not a checkpoint at all").unwrap();
    let m = ModelBuilder::new()
        .add("in", "input", &[("input_shape", "1:1:4")])
        .add("fc", "fully_connected", &[("unit", "2")])
        .add("loss", "mse", &[])
        .compile(&Default::default())
        .unwrap();
    match checkpoint::load(&m.exec, &path) {
        Err(Error::Checkpoint(_)) => {}
        other => panic!("expected Error::Checkpoint, got {other:?}"),
    }
    let _ = std::fs::remove_file(&path);
}

/// `checkpoint diff` golden output: a hand-written v1 file against a
/// saved v2 file. Classification (`-` only-in-a, `+` only-in-b, `~`
/// changed, identical count), name ordering and line format are all
/// pinned; v1 entries carry no dims, so the comparison is by element
/// count with flat `1:1:1:len` shapes reported.
#[test]
fn checkpoint_diff_golden_v1_vs_v2() {
    let a = compiled(8, "out");
    let v2 = tmp("diff_v2");
    a.save(&v2).unwrap();
    let v2_manifest = checkpoint::read_manifest(&v2).unwrap();
    let dim_of = |name: &str| {
        v2_manifest
            .iter()
            .find(|m| m.name == name)
            .map(|m| (m.dim, m.len))
            .unwrap()
    };
    let (_, h0w_len) = dim_of("h0:weight");
    let (_, h0b_len) = dim_of("h0:bias");
    let (outw_dim, outw_len) = dim_of("out:weight");
    let (outb_dim, outb_len) = dim_of("out:bias");

    // hand-write the v1 side: h0 matches, `gone:weight` exists only
    // here, `out:weight` has a wrong length, `out:bias` is missing
    let v1 = tmp("diff_v1");
    {
        let mut f = File::create(&v1).unwrap();
        f.write_all(b"NNTR").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&4u32.to_le_bytes()).unwrap();
        for (name, len) in [
            ("h0:weight", h0w_len),
            ("h0:bias", h0b_len),
            ("gone:weight", 99usize),
            ("out:weight", 100usize),
        ] {
            f.write_all(&(name.len() as u32).to_le_bytes()).unwrap();
            f.write_all(name.as_bytes()).unwrap();
            f.write_all(&(len as u32).to_le_bytes()).unwrap();
            for _ in 0..len {
                f.write_all(&0.5f32.to_le_bytes()).unwrap();
            }
        }
    }

    let out = checkpoint::diff_files(&v1, &v2).unwrap();
    let expected = format!(
        "a: {v1} (v1, 4 tensors)\n\
         b: {v2} (v2, 4 tensors)\n\
         - `gone:weight` 1:1:1:99 (99 f32) only in a\n\
         ~ `out:weight` 1:1:1:100 (100 f32) -> {outw_dim} ({outw_len} f32)\n\
         + `out:bias` {outb_dim} ({outb_len} f32) only in b\n\
         2 tensor(s) identical\n"
    );
    assert_eq!(out, expected, "diff output drifted from the golden form");

    // identical files: the diff is exactly the trailing count line
    let self_diff = checkpoint::diff_files(&v2, &v2).unwrap();
    assert!(
        self_diff.ends_with("4 tensor(s) identical\n"),
        "{self_diff}"
    );
    assert_eq!(self_diff.lines().count(), 3, "{self_diff}");

    let _ = std::fs::remove_file(&v1);
    let _ = std::fs::remove_file(&v2);
}
