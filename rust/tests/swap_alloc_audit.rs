//! Steady-state zero-allocation audit for the swap hot path.
//!
//! The swap engine's contract (DESIGN.md §Swap runtime) is that after
//! warmup, the evict/fetch workers allocate *nothing*: staging buffers
//! recycle through the channel, store slots are overwritten in place,
//! and the training thread's inline sync-fetch fallback reuses one
//! buffer. This binary installs the counting allocator from
//! `runtime::alloc_audit` and pins the post-warmup worker allocation
//! count to exactly zero — a single straggler (a `vec![0f32; n]` on a
//! fetch, a growing store slot) fails the test, which is the point:
//! this is how the PR that added it found the inline-fetch and
//! staging-capacity stragglers it fixed.
//!
//! Worker threads only: the training thread legitimately allocates
//! (batch binding, bookkeeping), so it never calls
//! `mark_thread_tracked`. Allocations under `TRACK_MIN_BYTES` (std
//! channel packet nodes) are below the audit's floor — the model is
//! sized so every offloaded tensor is far above it.

use nntrainer::graph::NodeDesc;
use nntrainer::layers::Props;
use nntrainer::model::{DeviceProfile, Session, TrainSpec};
use nntrainer::rng::Rng;
use nntrainer::runtime::alloc_audit::{arm, disarm, CountingAlloc, TRACK_MIN_BYTES};
use nntrainer::runtime::StoreKind;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn node(name: &str, ltype: &str, pairs: &[(&str, &str)]) -> NodeDesc {
    NodeDesc::new(name, ltype, Props::from_pairs(pairs.iter().copied()))
}

/// Conv net sized so offloadable activations are comfortably above the
/// audit's 4 KiB floor (4 x 16 x 16 = 1024 f32 per sample, batch 8).
fn audit_net() -> Vec<NodeDesc> {
    vec![
        node("in", "input", &[("input_shape", "4:16:16")]),
        node("c0", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("c1", "conv2d", &[("filters", "4"), ("kernel_size", "3"), ("padding", "same"), ("activation", "relu")]),
        node("flat", "flatten", &[]),
        node("head", "fully_connected", &[("unit", "8")]),
        node("loss", "mse", &[]),
    ]
}

fn swap_session(store: StoreKind) -> nntrainer::model::CompiledSession {
    let batch = 8usize;
    let full = nntrainer::compiler::plan_only(
        audit_net(),
        &nntrainer::compiler::CompileOpts { batch, ..Default::default() },
    )
    .unwrap()
    .pool_bytes;
    let cs = Session::describe(audit_net())
        .optimizer("sgd", &[("learning_rate", "0.01")])
        .configure(TrainSpec { batch: Some(batch), ..Default::default() })
        .compile_for(DeviceProfile {
            memory_budget_bytes: Some(full * 70 / 100),
            swap: true,
            swap_store: store,
            ..Default::default()
        })
        .unwrap();
    assert!(cs.model.exec.swap_active(), "budget did not engage the swap runtime");
    cs
}

fn run_iters(cs: &mut nntrainer::model::CompiledSession, n: usize, seed: u64) {
    let batch = cs.batch();
    let mut rng = Rng::new(seed);
    let mut input = vec![0f32; 4 * 16 * 16 * batch];
    let mut label = vec![0f32; 8 * batch];
    for _ in 0..n {
        rng.fill_uniform(&mut input, -1.0, 1.0);
        rng.fill_uniform(&mut label, 0.0, 1.0);
        cs.model.bind_batch(&input, &label).unwrap();
        cs.model.exec.try_train_iteration().unwrap();
    }
}

/// One test body for both halves of the audit — the counter is process
/// global, so concurrently-running `#[test]`s would contaminate each
/// other's armed windows.
#[test]
fn swap_worker_allocation_audit() {
    // -- negative control first: armed across warmup, the hook MUST see
    // the workers' first-touch staging allocations; otherwise the zero
    // below would be vacuous.
    {
        let mut cs = swap_session(StoreKind::Host);
        arm();
        run_iters(&mut cs, 2, 0xC0DE);
        let tracked = disarm();
        assert!(
            tracked > 0,
            "counting hook saw no warmup allocations — the audit is blind"
        );
    }

    // -- the contract: post-warmup, exactly zero tracked blocks — for
    // both store backends, across many iterations.
    for store in [StoreKind::Host, StoreKind::File] {
        let mut cs = swap_session(store);
        // warmup: staging buffers, store slots, and scratch all
        // first-touch here (all iterations stay in one "epoch" — no
        // mark_epoch — so the calibrated depth cannot move mid-audit)
        run_iters(&mut cs, 6, 0xA0D1);
        arm();
        run_iters(&mut cs, 12, 0xA0D2);
        let tracked = disarm();
        let stats = cs.model.exec.swap_stats().unwrap();
        assert!(
            stats.prefetches + stats.sync_fetches > 0,
            "audit exercised no swap traffic ({store:?})"
        );
        assert_eq!(
            tracked, 0,
            "swap workers allocated {tracked} block(s) >= {TRACK_MIN_BYTES} B \
             post-warmup ({store:?}) — a staging buffer or store slot is not \
             being reused"
        );
    }
}
